//! Computational-overlap analysis between consecutive layers
//! (paper §IV-G "Overlapping Definition" and §IV-H "Overlap Analysis with
//! Analytical Algorithm").
//!
//! Given a producer layer `n` and a consumer layer `n+1`, both with fixed
//! mappings, the analysis answers: *for every temporal step `t` of the
//! consumer, at which producer cycle is the whole input operation space
//! `I_t^{n+1}` ready?* The consumer step may start as soon as its inputs
//! are ready and an instance is free; the resulting schedule yields the
//! overlapped latency, the optimization metric of Fast-OverlaPIM.
//!
//! Two engines implement the analysis:
//!
//! * [`ExhaustiveOverlap`] — OverlaPIM's O(N·M) algorithm: compare every
//!   consumer input data space against every producer output data space
//!   and take the latest intersecting step. Kept as the runtime baseline
//!   (Fig. 14) and as the oracle for the analytical engine.
//! * [`AnalyticalOverlap`] — the paper's Eqs. 3–6: walk the producer's
//!   loop nest once per query, decoding the latest *finish step* of the
//!   input region directly (`O(#loops)` per step). The step index is a sum
//!   of independent per-dimension digit contributions, so the maximum over
//!   a box is the sum of per-dimension digit-walk maxima
//!   ([`LoopTable::max_finish_step_over_box`]).
//!
//! # Paper-to-code map
//!
//! | paper | here |
//! |-------|------|
//! | §IV-G overlapping definition, Fig. 4 | [`overlapped_latency`], [`OverlapResult`] |
//! | §IV-H Eqs. 3–6 analytical analysis | [`AnalyticalOverlap`] → [`ReadyTimes`] |
//! | §IV-H O(N·M) baseline (OverlaPIM) | [`ExhaustiveOverlap`] |
//! | input operation space `I_t^{n+1}` | [`LayerPair::step_input_boxes`] |
//! | §IV-J repeated fixed-neighbor analyses | [`OverlapCache`] (ready-times table) |
//! | §IV-I per-job ready queries (step 1) | [`OverlapCache`] (transform table) |
//!
//! # Memoization
//!
//! [`OverlapCache`] holds two sharded memo tables: ready times per
//! analyzed pair ([`PairKey`]), and `transform_schedule`'s per-job ready
//! queries per transformed pair ([`TransformKey`]). Both store the exact
//! analysis output keyed by stable fingerprints, so enabling either table
//! is observationally transparent — it can change wall-clock, never a
//! result. See the memoization section further down for the insert/peek
//! discipline.

use crate::dataspace::{AnalyticalGen, DataSpace, LoopTable, Range};
use crate::mapping::Mapping;
use crate::perf::LayerStats;
use crate::util::Fnv64;
use crate::workload::{Layer, LayerKind};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A box in *producer output* coordinates `[K, P, Q]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutBox {
    pub k: Range,
    pub p: Range,
    pub q: Range,
}

/// Analysis tuning knobs.
#[derive(Debug, Clone)]
pub struct OverlapConfig {
    /// Maximum consumer temporal steps probed per pair. Mappings with more
    /// steps are probed at an even stride (first and last always probed);
    /// the overlapped-latency estimate is then a lower bound that becomes
    /// exact when `steps <= max_probe_steps`. Bounded probing is what keeps
    /// whole-network search tractable; the final chosen mapping can be
    /// re-analyzed exactly.
    pub max_probe_steps: usize,
}

impl Default for OverlapConfig {
    fn default() -> Self {
        Self { max_probe_steps: 2048 }
    }
}

/// Ready times of consumer steps, possibly probed at a stride.
#[derive(Debug, Clone)]
pub struct ReadyTimes {
    /// `(consumer step index, ready cycle on the producer clock)`,
    /// ascending in step index.
    pub probes: Vec<(u64, u64)>,
    /// Total consumer temporal steps.
    pub total_steps: u64,
}

impl ReadyTimes {
    /// Latest ready cycle across probes (the whole-layer dependency).
    pub fn max_ready(&self) -> u64 {
        self.probes.iter().map(|&(_, r)| r).max().unwrap_or(0)
    }
}

/// Merge per-predecessor ready times into the consumer's effective ready
/// times (graph workloads, §IV-G generalized): each part is `(producer
/// start offset, pairwise ready times)` and a consumer step is ready only
/// when *every* predecessor has produced its region — the max over
/// `offset + ready`. A ready time of 0 means the region lies wholly in
/// padding (no dependence), so it contributes 0 rather than the offset.
///
/// The probe schedules of all parts align by construction: probe steps
/// are a pure function of the consumer's step count and the probe budget,
/// both shared across the predecessor set.
pub fn merge_ready_times(parts: &[(u64, &ReadyTimes)]) -> ReadyTimes {
    assert!(!parts.is_empty(), "merge needs at least one predecessor");
    let (off0, first) = parts[0];
    let mut probes: Vec<(u64, u64)> = first
        .probes
        .iter()
        .map(|&(t, r)| (t, if r == 0 { 0 } else { off0 + r }))
        .collect();
    for &(off, rt) in &parts[1..] {
        debug_assert_eq!(rt.total_steps, first.total_steps, "probe schedules must align");
        debug_assert_eq!(rt.probes.len(), probes.len(), "probe schedules must align");
        for (acc, &(t, r)) in probes.iter_mut().zip(&rt.probes) {
            debug_assert_eq!(acc.0, t, "probe schedules must align");
            if r > 0 {
                acc.1 = acc.1.max(off + r);
            }
        }
    }
    ReadyTimes { probes, total_steps: first.total_steps }
}

/// A producer/consumer pair under analysis: layers, mappings, performance
/// stats, and the precomputed coordinate transform between the consumer's
/// input space and the producer's output space.
pub struct LayerPair<'a> {
    pub producer: &'a Layer,
    pub producer_mapping: &'a Mapping,
    pub producer_stats: &'a LayerStats,
    pub consumer: &'a Layer,
    pub consumer_mapping: &'a Mapping,
    pub consumer_stats: &'a LayerStats,
    /// Producer loop table (decodes finish steps analytically).
    pub producer_table: LoopTable,
    /// Consumer loop table (decodes consumer data spaces).
    pub consumer_table: LoopTable,
    /// Pooling factor between the layers (producer `pool_after`).
    pool: u64,
    /// Producer movement cycles amortized per producer step: outputs
    /// stream to the consumer's input locations as they complete.
    per_step_move: u64,
    /// Consumer banks with distinct input regions (see
    /// [`LoopTable::representative_banks`]).
    consumer_rep_banks: Vec<u64>,
}

impl<'a> LayerPair<'a> {
    pub fn new(
        producer: (&'a Layer, &'a Mapping, &'a LayerStats),
        consumer: (&'a Layer, &'a Mapping, &'a LayerStats),
    ) -> LayerPair<'a> {
        let producer_table = LoopTable::new(producer.1);
        let consumer_table = LoopTable::new(consumer.1);
        // Banks differing only in K/N spatial digits consume identical
        // input regions — except for depthwise consumers, whose K digit
        // *selects* the input channel, so K must stay in the
        // representative set there.
        use crate::mapping::Dim;
        let rep_dims: &[Dim] = if matches!(
            consumer.0.kind,
            LayerKind::Depthwise | LayerKind::Elementwise
        ) {
            &[Dim::K, Dim::P, Dim::Q, Dim::C, Dim::R, Dim::S]
        } else {
            &[Dim::P, Dim::Q, Dim::C, Dim::R, Dim::S]
        };
        let consumer_rep_banks = consumer_table.representative_banks(rep_dims);
        let steps = producer.2.temporal_steps.max(1);
        LayerPair {
            producer: producer.0,
            producer_mapping: producer.1,
            producer_stats: producer.2,
            consumer: consumer.0,
            consumer_mapping: consumer.1,
            consumer_stats: consumer.2,
            producer_table,
            consumer_table,
            consumer_rep_banks,
            pool: producer.0.pool_after.max(1),
            per_step_move: producer.2.movement_cycles.div_ceil(steps),
        }
    }

    /// Convert one consumer data space's *input* region into boxes in the
    /// producer's output coordinate system, clamped to the producer's real
    /// (unpadded) bounds. Empty if the region lies wholly in padding.
    pub fn input_boxes(&self, ds: &DataSpace) -> Vec<OutBox> {
        match self.consumer.kind {
            LayerKind::Fc => self.fc_input_boxes(ds),
            LayerKind::Conv | LayerKind::MatMul => {
                self.conv_input_boxes(ds).into_iter().collect()
            }
            // Elementwise joins share the depthwise channel-identity rule:
            // output channel k reads input channel k (their C loop is
            // trivial by encoding), with a 1×1 receptive field.
            LayerKind::Depthwise | LayerKind::Elementwise => {
                self.depthwise_input_boxes(ds).into_iter().collect()
            }
        }
    }

    /// Depthwise consumers read input channel `k` for output channel `k`
    /// (their `C` loop is trivial by encoding), so the consumed producer
    /// channel range is the data space's *K* range; the spatial receptive
    /// field behaves exactly like a convolution's.
    fn depthwise_input_boxes(&self, ds: &DataSpace) -> Option<OutBox> {
        self.conv_like_input_boxes(ds.k, ds)
    }

    fn conv_input_boxes(&self, ds: &DataSpace) -> Option<OutBox> {
        // Input channels of the consumer are the producer's output channels.
        self.conv_like_input_boxes(ds.c, ds)
    }

    /// Shared conv-shaped receptive-field mapping: `channels` is the
    /// consumed input-channel range in producer output-channel
    /// coordinates (the C range for convolutions, the K range for
    /// depthwise); the spatial region is shifted by padding, clamped to
    /// the consumer's real input extent, then mapped through pooling to
    /// producer output rows.
    fn conv_like_input_boxes(&self, channels: Range, ds: &DataSpace) -> Option<OutBox> {
        let (kp, pp, qp) = (self.producer.k, self.producer.p, self.producer.q);
        let k = channels.clamp(kp)?;
        let y = shift_clamp(ds.input_y(self.consumer.stride), self.consumer.pad, pp / self.pool)?;
        let x = shift_clamp(ds.input_x(self.consumer.stride), self.consumer.pad, qp / self.pool)?;
        let p = unpool(y, self.pool).clamp(pp)?;
        let q = unpool(x, self.pool).clamp(qp)?;
        Some(OutBox { k, p, q })
    }

    /// FC consumers flatten the producer's `[K, P', Q']` output (after
    /// pooling) row-major into their C axis; a contiguous C range maps to
    /// up to three boxes: a partial first K-plane, full middle planes, and
    /// a partial last plane. For the *latest finish* query only the max
    /// corner matters, but the exhaustive engine needs the true region.
    fn fc_input_boxes(&self, ds: &DataSpace) -> Vec<OutBox> {
        let (kp, pp, qp) = (self.producer.k, self.producer.p, self.producer.q);
        let (ppool, qpool) = (pp / self.pool.max(1), qp / self.pool.max(1));
        let plane = (ppool * qpool).max(1);
        let total = kp * plane;
        let Some(c) = ds.c.clamp(total) else { return vec![] };
        let mut boxes = Vec::new();
        let k_lo = c.lo / plane;
        let k_hi = (c.hi - 1) / plane; // inclusive
        if k_lo == k_hi {
            // Single plane: a row-major flat segment inside one K slice.
            boxes.extend(flat_segment_boxes(k_lo, c.lo % plane, (c.hi - 1) % plane, qpool));
        } else {
            // Head partial plane.
            boxes.extend(flat_segment_boxes(k_lo, c.lo % plane, plane - 1, qpool));
            // Middle full planes.
            if k_hi > k_lo + 1 {
                boxes.push(OutBox {
                    k: Range::new(k_lo + 1, k_hi),
                    p: Range::new(0, ppool),
                    q: Range::new(0, qpool),
                });
            }
            // Tail partial plane.
            boxes.extend(flat_segment_boxes(k_hi, 0, (c.hi - 1) % plane, qpool));
        }
        // Map pooled coordinates back to producer output coordinates.
        boxes
            .into_iter()
            .filter_map(|b| {
                Some(OutBox {
                    k: b.k,
                    p: scale_range(b.p, self.pool).clamp(pp)?,
                    q: scale_range(b.q, self.pool).clamp(qp)?,
                })
            })
            .collect()
    }

    /// Ready cycle for a set of input boxes: the finish cycle of the
    /// latest-producing box corner plus the per-step output transfer.
    /// This is the Eqs. 3–6 query, also used per-job by the transformation.
    pub fn ready_cycle_of_boxes(&self, boxes: &[OutBox]) -> u64 {
        let mut latest: Option<u64> = None;
        for b in boxes {
            let step = self.producer_table.max_finish_step_over_box(b.k, b.p, b.q);
            latest = Some(latest.map_or(step, |l: u64| l.max(step)));
        }
        match latest {
            // Inputs entirely in padding: ready immediately.
            None => 0,
            Some(step) => {
                self.producer_stats.step_finish_cycle(step) + self.per_step_move
            }
        }
    }

    /// The input boxes of the whole step `t` across all consumer
    /// instances (paper §IV-G: the ready time of `I_t^{n+1}` covers the
    /// input operation spaces of *all* hardware instances at that step).
    /// The union is a set of per-bank boxes — NOT their bounding box,
    /// which would wildly overapproximate when spatial splits are coarse.
    /// Banks differing only in K/N spatial digits consume identical input
    /// regions, so only representatives over {P, Q, C, R, S} are queried.
    pub fn step_input_boxes(&self, step: u64) -> Vec<OutBox> {
        let mut boxes = Vec::new();
        for &bank in &self.consumer_rep_banks {
            let ds = self.consumer_table.space_at(bank, step);
            boxes.extend(self.input_boxes(&ds));
        }
        boxes
    }

    /// The probe steps for this pair under `config`.
    pub fn probe_steps(&self, config: &OverlapConfig) -> Vec<u64> {
        let total = self.consumer_table.total_steps;
        probe_indices(total, config.max_probe_steps as u64)
    }
}

/// Shift a padded-coordinate range left by `pad` and clamp to `[0, bound)`.
fn shift_clamp(r: Range, pad: u64, bound: u64) -> Option<Range> {
    let lo = r.lo.saturating_sub(pad);
    let hi = r.hi.saturating_sub(pad);
    if lo >= hi {
        return None;
    }
    Range::new(lo, hi).clamp(bound)
}

/// Map consumer-input (post-pool) rows to producer output rows.
fn unpool(r: Range, pool: u64) -> Range {
    Range::new(r.lo * pool, r.hi * pool)
}

fn scale_range(r: Range, pool: u64) -> Range {
    Range::new(r.lo * pool, r.hi * pool)
}

/// Boxes covering the row-major flat segment `[lo, hi]` (inclusive) inside
/// one pooled K-plane of width `q`: up to three (partial head row, full
/// middle rows, partial tail row).
fn flat_segment_boxes(k: u64, lo: u64, hi: u64, q: u64) -> Vec<OutBox> {
    debug_assert!(lo <= hi);
    let kr = Range::new(k, k + 1);
    let (row_lo, col_lo) = (lo / q, lo % q);
    let (row_hi, col_hi) = (hi / q, hi % q);
    if row_lo == row_hi {
        return vec![OutBox {
            k: kr,
            p: Range::new(row_lo, row_lo + 1),
            q: Range::new(col_lo, col_hi + 1),
        }];
    }
    let mut out = Vec::with_capacity(3);
    out.push(OutBox { k: kr, p: Range::new(row_lo, row_lo + 1), q: Range::new(col_lo, q) });
    if row_hi > row_lo + 1 {
        out.push(OutBox { k: kr, p: Range::new(row_lo + 1, row_hi), q: Range::new(0, q) });
    }
    out.push(OutBox { k: kr, p: Range::new(row_hi, row_hi + 1), q: Range::new(0, col_hi + 1) });
    out
}

/// Evenly-strided probe indices over `[0, total)`, always including the
/// first and last index, at most `max` of them.
pub fn probe_indices(total: u64, max: u64) -> Vec<u64> {
    assert!(max >= 2, "need at least first+last probes");
    if total <= max {
        return (0..total).collect();
    }
    let stride = total.div_ceil(max);
    let mut v: Vec<u64> = (0..total).step_by(stride as usize).collect();
    if *v.last().unwrap() != total - 1 {
        v.push(total - 1);
    }
    v
}

/// The overlap-analysis interface shared by both engines.
pub trait OverlapAnalysis {
    /// Ready cycles (producer clock) for the consumer's probed steps.
    fn ready_times(&self, pair: &LayerPair<'_>) -> ReadyTimes;

    /// Engine name for reports.
    fn name(&self) -> &'static str;
}

/// The paper's analytical engine (Eqs. 3–6).
#[derive(Debug, Clone, Default)]
pub struct AnalyticalOverlap {
    pub config: OverlapConfig,
}

impl AnalyticalOverlap {
    pub fn new(config: OverlapConfig) -> Self {
        Self { config }
    }
}

impl OverlapAnalysis for AnalyticalOverlap {
    fn ready_times(&self, pair: &LayerPair<'_>) -> ReadyTimes {
        let steps = pair.probe_steps(&self.config);
        let probes = steps
            .into_iter()
            .map(|t| {
                let boxes = pair.step_input_boxes(t);
                (t, pair.ready_cycle_of_boxes(&boxes))
            })
            .collect();
        ReadyTimes { probes, total_steps: pair.consumer_table.total_steps }
    }

    fn name(&self) -> &'static str {
        "analytical"
    }
}

/// OverlaPIM's exhaustive engine: materialize all producer data spaces and
/// compare every consumer input region against all of them (§IV-H:
/// "O(N·M) time complexity with overheads").
#[derive(Debug, Clone, Default)]
pub struct ExhaustiveOverlap {
    pub config: OverlapConfig,
}

impl ExhaustiveOverlap {
    pub fn new(config: OverlapConfig) -> Self {
        Self { config }
    }
}

impl OverlapAnalysis for ExhaustiveOverlap {
    fn ready_times(&self, pair: &LayerPair<'_>) -> ReadyTimes {
        // N producer data spaces, materialized up front (OverlaPIM's flow).
        let producer_spaces = AnalyticalGen::generate(pair.producer_mapping);
        let steps = pair.probe_steps(&self.config);
        let probes = steps
            .into_iter()
            .map(|t| {
                let boxes = pair.step_input_boxes(t);
                let mut latest: Option<u64> = None;
                for b in &boxes {
                    for ds in &producer_spaces {
                        if ds.output_intersects(&b.k, &b.p, &b.q) {
                            latest = Some(latest.map_or(ds.step, |l: u64| l.max(ds.step)));
                        }
                    }
                }
                let ready = match latest {
                    None => 0,
                    Some(step) => {
                        pair.producer_stats.step_finish_cycle(step) + pair.per_step_move
                    }
                };
                (t, ready)
            })
            .collect();
        ReadyTimes { probes, total_steps: pair.consumer_table.total_steps }
    }

    fn name(&self) -> &'static str {
        "exhaustive"
    }
}

/// Result of the overlapped-latency evaluation for one pair (§IV-G).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OverlapResult {
    /// Consumer end cycle on the producer's clock (includes the consumer's
    /// trailing data movement).
    pub overlapped_end: u64,
    /// Latency the consumer adds beyond the producer's end — the quantity
    /// whole-network optimization sums.
    pub added_latency: u64,
    /// Cycles saved vs. strictly sequential execution.
    pub saving: u64,
    /// Fraction of the consumer's sequential latency hidden by overlap
    /// (Fig. 4's normalized overlapped latency).
    pub overlap_fraction: f64,
}

/// Evaluate the overlapped latency of the consumer given its step ready
/// times.
///
/// Consumer steps execute in order across all its banks in lock-step;
/// step `t` starts at `max(ready_t, finish_{t-1})`, so the end time is
/// `max_t (ready_t + (T - t)·c)` with `c` the consumer step latency —
/// exact when every step is probed, a lower bound otherwise.
pub fn overlapped_latency(
    producer_stats: &LayerStats,
    consumer_stats: &LayerStats,
    ready: &ReadyTimes,
) -> OverlapResult {
    overlapped_latency_at(producer_stats.latency_cycles, consumer_stats, ready)
}

/// [`overlapped_latency`] against an explicit producer end time instead of
/// a single producer's stats — the graph generalization, where the
/// "producer end" is the latest finish across the whole predecessor set
/// and `ready` is their merged ready times ([`merge_ready_times`]), all on
/// one shared clock.
pub fn overlapped_latency_at(
    producer_end: u64,
    consumer_stats: &LayerStats,
    ready: &ReadyTimes,
) -> OverlapResult {
    let c = consumer_stats.step_cycles.max(1);
    let t_total = ready.total_steps.max(1);
    let mut end = t_total * c; // all-ready-at-0 floor
    for &(t, r) in &ready.probes {
        end = end.max(r + (t_total - t) * c);
    }
    let overlapped_end = end + consumer_stats.movement_cycles;
    let sequential_end = producer_end + consumer_stats.latency_cycles;
    let added_latency = overlapped_end.saturating_sub(producer_end);
    let saving = sequential_end.saturating_sub(overlapped_end);
    OverlapResult {
        overlapped_end,
        added_latency,
        saving,
        overlap_fraction: saving as f64 / consumer_stats.latency_cycles.max(1) as f64,
    }
}

// ---------------------------------------------------------------------------
// Analysis memoization (§IV-J acceleration).
//
// The whole-network sweep evaluates N layers × k candidates, and each
// candidate is scored against a *fixed* neighbor mapping. The same
// (producer, consumer) pair recurs whenever an incumbent is re-scored — in
// coordinate-descent refinement passes, in the final forward evaluation
// pass, and across the baseline-matrix searches — and the expensive halves
// of both analyses are pure functions of the pair, so recomputing them is
// pure waste. [`OverlapCache`] therefore holds TWO memo tables over the
// same sharded skeleton:
//
// * the **ready-times table** (`PairKey` → [`ReadyTimes`]) memoizes the
//   per-step overlap analysis (Eqs. 3–6);
// * the **transform table** (`TransformKey` → per-job ready queries)
//   memoizes `transform_schedule`'s `(bank, step)` job queries, which
//   dominate the Transform-metric hot path (§IV-I step 1 — the sort and
//   makespan arithmetic after it are cheap and recomputed every time).
//
// Both tables key entries by stable fingerprints of the two sides plus the
// probe configuration (the ready-times table also tags the engine), store
// the exact analysis output (observational transparency: cache on/off
// cannot change any result), and follow the same peek/insert discipline:
// recurring chosen-pair lookups insert, one-shot candidate lookups only
// peek. Shards keep parallel workers off each other's locks.
// ---------------------------------------------------------------------------

/// Cache key for one analyzed pair: stable fingerprints of the producer
/// and consumer sides plus the analysis configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PairKey {
    pub producer: u64,
    pub consumer: u64,
    /// `OverlapConfig::max_probe_steps` the entry was computed with.
    pub probe: u64,
    /// Engine tag (the two engines agree analytically, but keying them
    /// apart keeps the cache observationally transparent even if one
    /// regresses).
    pub engine: u64,
    /// Predecessor-set tag: 0 for a plain producer→consumer pair; for a
    /// merged multi-predecessor entry ([`merged_pair_cache_key`]) the
    /// predecessor count, with the offset-aware set fingerprint folded
    /// into `producer`. Keying the set apart keeps merged entries from
    /// aliasing any pairwise entry.
    pub pred_set: u64,
}

/// Fingerprint of one side of a pair: everything `ready_times` reads from
/// it — layer shape, mapping structure, and the latency parameters of its
/// stats (step length, movement, step count).
fn side_fingerprint(layer: &Layer, mapping: &Mapping, stats: &LayerStats) -> u64 {
    let mut h = Fnv64::new();
    h.write(layer.fingerprint());
    h.write(mapping.fingerprint());
    h.write(stats.step_cycles);
    h.write(stats.movement_cycles);
    h.write(stats.temporal_steps);
    h.finish()
}

/// Build the cache key for a pair under a probe budget and engine tag.
pub fn pair_cache_key(pair: &LayerPair<'_>, engine: u64, max_probe_steps: usize) -> PairKey {
    PairKey {
        producer: side_fingerprint(pair.producer, pair.producer_mapping, pair.producer_stats),
        consumer: side_fingerprint(pair.consumer, pair.consumer_mapping, pair.consumer_stats),
        probe: max_probe_steps as u64,
        engine,
        pred_set: 0,
    }
}

/// Build the cache key for a *merged* multi-predecessor analysis
/// ([`merge_ready_times`]): `parts` pairs each predecessor's start offset
/// with its pairwise analysis. The producer fingerprint covers every
/// predecessor side *and* its offset (merged ready times depend on both);
/// `pred_set` carries the set size so merged entries can never alias
/// plain pairs.
pub fn merged_pair_cache_key(
    parts: &[(u64, &LayerPair<'_>)],
    engine: u64,
    max_probe_steps: usize,
) -> PairKey {
    assert!(!parts.is_empty(), "merged key needs at least one predecessor");
    let mut h = Fnv64::new();
    for &(offset, pair) in parts {
        h.write(side_fingerprint(pair.producer, pair.producer_mapping, pair.producer_stats));
        h.write(offset);
    }
    let consumer = parts[0].1;
    PairKey {
        producer: h.finish(),
        consumer: side_fingerprint(
            consumer.consumer,
            consumer.consumer_mapping,
            consumer.consumer_stats,
        ),
        probe: max_probe_steps as u64,
        engine,
        pred_set: parts.len() as u64,
    }
}

/// Cache key for the per-job ready queries of one transformed pair
/// (`transform_schedule`'s step 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TransformKey {
    pub producer: u64,
    pub consumer: u64,
    /// `TransformConfig::max_probe_jobs` the entry was computed with.
    pub probe_jobs: u64,
    /// Predecessor-set tag, exactly as [`PairKey::pred_set`]: 0 for plain
    /// pairs, the set size for merged multi-predecessor job queries.
    pub pred_set: u64,
}

/// Build the transform-table key for a pair under a job-probe budget.
///
/// No engine tag: the transformation's per-job queries always decode
/// producer finish steps analytically, whichever engine scores the pair's
/// plain overlap.
pub fn transform_cache_key(pair: &LayerPair<'_>, max_probe_jobs: usize) -> TransformKey {
    TransformKey {
        producer: side_fingerprint(pair.producer, pair.producer_mapping, pair.producer_stats),
        consumer: side_fingerprint(pair.consumer, pair.consumer_mapping, pair.consumer_stats),
        probe_jobs: max_probe_jobs as u64,
        pred_set: 0,
    }
}

/// Transform-table key for a merged multi-predecessor job query, mirroring
/// [`merged_pair_cache_key`].
pub fn merged_transform_cache_key(
    parts: &[(u64, &LayerPair<'_>)],
    max_probe_jobs: usize,
) -> TransformKey {
    assert!(!parts.is_empty(), "merged key needs at least one predecessor");
    let mut h = Fnv64::new();
    for &(offset, pair) in parts {
        h.write(side_fingerprint(pair.producer, pair.producer_mapping, pair.producer_stats));
        h.write(offset);
    }
    let consumer = parts[0].1;
    TransformKey {
        producer: h.finish(),
        consumer: side_fingerprint(
            consumer.consumer,
            consumer.consumer_mapping,
            consumer.consumer_stats,
        ),
        probe_jobs: max_probe_jobs as u64,
        pred_set: parts.len() as u64,
    }
}

/// Split hit/miss counters of [`OverlapCache`]'s two memo tables, plus
/// the search-side memo counters the cache aggregates for reporting (the
/// guided engines' genome score memo and the performance model's
/// per-nest delta-state).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Ready-times table (per-step overlap analysis) hits.
    pub ready_hits: u64,
    /// Ready-times table misses.
    pub ready_misses: u64,
    /// Transform table (per-job ready queries) hits.
    pub transform_hits: u64,
    /// Transform table misses.
    pub transform_misses: u64,
    /// Genome score memo hits — each one is a duplicate offspring a
    /// guided engine proposed and did not have to re-price.
    pub genome_hits: u64,
    /// Genome score memo misses (distinct genomes actually priced).
    pub genome_misses: u64,
    /// Per-nest delta-state hits in incremental evaluation
    /// ([`crate::perf::EvalDelta`]).
    pub delta_hits: u64,
    /// Per-nest delta-state misses (sub-nest aggregates computed).
    pub delta_misses: u64,
}

impl CacheStats {
    /// Total hits across the two *analysis* tables (ready + transform).
    /// The genome/delta counters are deliberately excluded: plan-level
    /// `cache_hits` deltas and the warm-replay tests count overlap
    /// analyses avoided, not search-side micro-memos.
    pub fn hits(&self) -> u64 {
        self.ready_hits + self.transform_hits
    }

    /// Total misses across the two analysis tables.
    pub fn misses(&self) -> u64 {
        self.ready_misses + self.transform_misses
    }

    /// The counters as stable `(name, value)` pairs — the one naming
    /// authority every stats surface (JSON, `--stats`, the metrics
    /// registry) renders from.
    pub fn fields(&self) -> [(&'static str, u64); 8] {
        [
            ("ready_hits", self.ready_hits),
            ("ready_misses", self.ready_misses),
            ("transform_hits", self.transform_hits),
            ("transform_misses", self.transform_misses),
            ("genome_hits", self.genome_hits),
            ("genome_misses", self.genome_misses),
            ("delta_hits", self.delta_hits),
            ("delta_misses", self.delta_misses),
        ]
    }
}

const CACHE_SHARDS: usize = 16;

/// Default per-shard entry cap (16 shards × 256 = 4096 entries per
/// table). Recurring-pair lookups ([`OverlapCache::get_or_compute`],
/// [`OverlapCache::transform_get_or_compute`]) insert on miss; one-shot
/// candidate lookups ([`OverlapCache::peek_or_compute`] and its transform
/// twin) never do, so the population is O(chain length × passes) in
/// practice and the cap is a memory backstop — a full shard simply
/// computes through without inserting, which can cost a recomputation
/// later but can never change a result.
const CACHE_SHARD_CAP: usize = 256;

/// Key types that place themselves into a shard deterministically (the
/// std hasher is randomized per process; fingerprint keys are already
/// well-mixed words, so a cheap xor-fold suffices).
trait ShardKey: Eq + std::hash::Hash + Copy {
    fn shard_hash(&self) -> u64;
}

impl ShardKey for PairKey {
    fn shard_hash(&self) -> u64 {
        self.producer
            ^ self.consumer.rotate_left(17)
            ^ self.probe
            ^ self.engine
            ^ self.pred_set.rotate_left(41)
    }
}

impl ShardKey for TransformKey {
    fn shard_hash(&self) -> u64 {
        self.producer
            ^ self.consumer.rotate_left(17)
            ^ self.probe_jobs.rotate_left(31)
            ^ self.pred_set.rotate_left(41)
    }
}

/// One sharded, thread-safe, bounded memo table — the locking and
/// counting skeleton shared by the ready-times and transform tables.
///
/// Lookups take one shard lock for a hash-map probe; the (expensive)
/// analysis itself always runs outside any lock, so parallel workers never
/// serialize on each other's computations — at worst two workers race to
/// compute the same entry and the first insertion wins (both computed the
/// same pure value, so the race is benign and deterministic).
struct ShardedMemo<K, V> {
    shards: Vec<Mutex<HashMap<K, Arc<V>>>>,
    shard_cap: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<K: ShardKey, V> ShardedMemo<K, V> {
    fn new(shard_cap: usize) -> ShardedMemo<K, V> {
        ShardedMemo {
            shards: (0..CACHE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            shard_cap,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    #[inline]
    fn shard(&self, key: &K) -> &Mutex<HashMap<K, Arc<V>>> {
        &self.shards[(key.shard_hash() as usize) % CACHE_SHARDS]
    }

    fn fetch<F>(&self, key: K, store: bool, compute: F) -> Arc<V>
    where
        F: FnOnce() -> V,
    {
        let shard = self.shard(&key);
        if let Some(v) = shard.lock().unwrap().get(&key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(v);
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let v = Arc::new(compute());
        if store {
            let mut guard = shard.lock().unwrap();
            if let Some(existing) = guard.get(&key) {
                // Lost a benign race: another worker inserted the same pure
                // value; keep the first insertion.
                return Arc::clone(existing);
            }
            if guard.len() < self.shard_cap {
                guard.insert(key, Arc::clone(&v));
            }
        }
        v
    }

    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

/// The analysis memoizer: a ready-times table ([`ReadyTimes`] per
/// [`PairKey`]) and a transform table (per-job ready queries per
/// [`TransformKey`]) over the same sharded skeleton. Shared by every
/// metric job of a whole-network search; all methods are `&self` and
/// thread-safe.
pub struct OverlapCache {
    ready: ShardedMemo<PairKey, ReadyTimes>,
    transform: ShardedMemo<TransformKey, Vec<(u64, u64)>>,
    /// Aggregated counters of the per-search-call genome score memo
    /// (duplicate-offspring dedup). The memo itself lives and dies with
    /// one engine call; only its counts roll up here.
    genome_hits: AtomicU64,
    genome_misses: AtomicU64,
    /// Aggregated counters of the per-search-call evaluation delta-state
    /// ([`crate::perf::EvalDelta`]).
    delta_hits: AtomicU64,
    delta_misses: AtomicU64,
}

impl OverlapCache {
    pub fn new() -> OverlapCache {
        Self::with_shard_cap(CACHE_SHARD_CAP)
    }

    /// Cache whose tables each hold at most `16 × shard_cap` entries (0 =
    /// store nothing, i.e. a pure pass-through that still counts
    /// hits/misses).
    pub fn with_shard_cap(shard_cap: usize) -> OverlapCache {
        OverlapCache {
            ready: ShardedMemo::new(shard_cap),
            transform: ShardedMemo::new(shard_cap),
            genome_hits: AtomicU64::new(0),
            genome_misses: AtomicU64::new(0),
            delta_hits: AtomicU64::new(0),
            delta_misses: AtomicU64::new(0),
        }
    }

    /// Roll one engine call's genome-memo counts into the aggregate.
    pub fn add_genome_counts(&self, hits: u64, misses: u64) {
        self.genome_hits.fetch_add(hits, Ordering::Relaxed);
        self.genome_misses.fetch_add(misses, Ordering::Relaxed);
    }

    /// Roll one engine call's delta-state counts into the aggregate.
    pub fn add_delta_counts(&self, hits: u64, misses: u64) {
        self.delta_hits.fetch_add(hits, Ordering::Relaxed);
        self.delta_misses.fetch_add(misses, Ordering::Relaxed);
    }

    /// Fetch the ready-times entry for `key`, computing it on a miss and
    /// inserting the result while the shard has room. `compute` runs
    /// outside the shard lock.
    pub fn get_or_compute<F>(&self, key: PairKey, compute: F) -> Arc<ReadyTimes>
    where
        F: FnOnce() -> ReadyTimes,
    {
        self.ready.fetch(key, true, compute)
    }

    /// Fetch the ready-times entry for `key`, computing on a miss
    /// **without inserting**. For lookups whose key is unlikely to recur
    /// (each candidate draw of a search analyzes a fresh pair exactly
    /// once): they still profit from entries the recurring paths stored,
    /// but must not flush those entries out of the bounded shards with
    /// write-once garbage.
    pub fn peek_or_compute<F>(&self, key: PairKey, compute: F) -> Arc<ReadyTimes>
    where
        F: FnOnce() -> ReadyTimes,
    {
        self.ready.fetch(key, false, compute)
    }

    /// Fetch the per-job ready queries for `key` (the expensive step 1 of
    /// `transform_schedule`), computing and inserting on a miss.
    pub fn transform_get_or_compute<F>(
        &self,
        key: TransformKey,
        compute: F,
    ) -> Arc<Vec<(u64, u64)>>
    where
        F: FnOnce() -> Vec<(u64, u64)>,
    {
        self.transform.fetch(key, true, compute)
    }

    /// Fetch the per-job ready queries for `key`, computing on a miss
    /// without inserting — the candidate-draw discipline, exactly as
    /// [`OverlapCache::peek_or_compute`].
    pub fn transform_peek_or_compute<F>(
        &self,
        key: TransformKey,
        compute: F,
    ) -> Arc<Vec<(u64, u64)>>
    where
        F: FnOnce() -> Vec<(u64, u64)>,
    {
        self.transform.fetch(key, false, compute)
    }

    /// Total hits across both tables.
    pub fn hits(&self) -> u64 {
        self.ready.hits() + self.transform.hits()
    }

    /// Total misses across both tables.
    pub fn misses(&self) -> u64 {
        self.ready.misses() + self.transform.misses()
    }

    /// Split counters of the two tables plus the aggregated search-side
    /// memo counters.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            ready_hits: self.ready.hits(),
            ready_misses: self.ready.misses(),
            transform_hits: self.transform.hits(),
            transform_misses: self.transform.misses(),
            genome_hits: self.genome_hits.load(Ordering::Relaxed),
            genome_misses: self.genome_misses.load(Ordering::Relaxed),
            delta_hits: self.delta_hits.load(Ordering::Relaxed),
            delta_misses: self.delta_misses.load(Ordering::Relaxed),
        }
    }

    /// Number of distinct entries currently held (both tables).
    pub fn len(&self) -> usize {
        self.ready.len() + self.transform.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for OverlapCache {
    fn default() -> OverlapCache {
        OverlapCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Arch;
    use crate::mapping::{Dim, Loop, Mapping};
    use crate::mapspace::MapSpace;
    use crate::perf::PerfModel;
    use crate::util::rng::SplitMix64;
    use crate::workload::Layer;

    fn conv_pair() -> (Layer, Layer) {
        (
            Layer::conv("a", 1, 8, 8, 8, 8, 3, 3, 1, 1),
            Layer::conv("b", 1, 8, 8, 8, 8, 3, 3, 1, 1),
        )
    }

    fn simple_mapping(k: u64, p: u64, q: u64, c: u64) -> Mapping {
        // All output dims temporal at bank level in K->P->Q order,
        // reduction serial in the interior, single bank.
        Mapping::new(vec![
            vec![],
            vec![],
            vec![
                Loop::temporal(Dim::K, k),
                Loop::temporal(Dim::P, p),
                Loop::temporal(Dim::Q, q),
            ],
            vec![
                Loop::spatial(Dim::K, 8 / k),
                Loop::spatial(Dim::P, 8 / p),
                Loop::spatial(Dim::Q, 8 / q),
                Loop::temporal(Dim::C, c),
                Loop::temporal(Dim::R, 3),
                Loop::temporal(Dim::S, 3),
            ],
        ])
    }

    fn eval<'a>(
        arch: &Arch,
        layer: &Layer,
        mapping: &Mapping,
    ) -> crate::perf::LayerStats {
        PerfModel::new(arch).evaluate(layer, mapping)
    }

    #[test]
    fn analytical_equals_exhaustive_on_simple_pair() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(4, 2, 1, 8);
        let mb = simple_mapping(2, 4, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let ana = AnalyticalOverlap::default().ready_times(&pair);
        let exh = ExhaustiveOverlap::default().ready_times(&pair);
        assert_eq!(ana.probes, exh.probes);
        assert_eq!(ana.total_steps, exh.total_steps);
    }

    #[test]
    fn analytical_equals_exhaustive_on_sampled_pairs() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let pm = PerfModel::new(&arch);
        let msa = MapSpace::with_defaults(&arch, &la);
        let msb = MapSpace::with_defaults(&arch, &lb);
        let mut rng = SplitMix64::new(77);
        let mut checked = 0;
        for _ in 0..12 {
            let (Some(ma), Some(mb)) = (msa.sample(&mut rng), msb.sample(&mut rng)) else {
                continue;
            };
            // Keep the exhaustive side small.
            if ma.temporal_steps() * ma.spatial_instances() > 4096
                || mb.temporal_steps() > 2048
            {
                continue;
            }
            let sa = pm.evaluate(&la, &ma);
            let sb = pm.evaluate(&lb, &mb);
            let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
            let ana = AnalyticalOverlap::default().ready_times(&pair);
            let exh = ExhaustiveOverlap::default().ready_times(&pair);
            assert_eq!(ana.probes, exh.probes, "ma={ma:?} mb={mb:?}");
            checked += 1;
        }
        assert!(checked >= 5, "too few pairs checked: {checked}");
    }

    #[test]
    fn matched_production_order_overlaps_well() {
        // Producer emits P rows in order; consumer consumes them in the
        // same order -> most steps ready early -> large saving.
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(1, 8, 1, 8); // P-major production
        let mb = simple_mapping(1, 8, 1, 8); // P-major consumption
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let ready = AnalyticalOverlap::default().ready_times(&pair);
        let res = overlapped_latency(&sa, &sb, &ready);
        assert!(
            res.overlap_fraction > 0.3,
            "aligned mappings should overlap: {res:?}"
        );
        // First consumer row only needs the first two producer rows.
        let first_ready = ready.probes[0].1;
        assert!(first_ready < sa.latency_cycles / 2);
    }

    #[test]
    fn mismatched_order_overlaps_poorly() {
        // Producer emits K-major (all K for row 0 late); consumer needs
        // all C (=K of producer) for its first output -> ready late.
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(8, 1, 1, 8); // K innermost... K outer-major
        let mb = simple_mapping(1, 8, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let ready = AnalyticalOverlap::default().ready_times(&pair);
        // Every consumer step needs the full K range of some rows ->
        // ready times near the producer end.
        let res = overlapped_latency(&sa, &sb, &ready);
        let aligned = {
            let ma2 = simple_mapping(1, 8, 1, 8);
            let sa2 = eval(&arch, &la, &ma2);
            let pair2 = LayerPair::new((&la, &ma2, &sa2), (&lb, &mb, &sb));
            let ready2 = AnalyticalOverlap::default().ready_times(&pair2);
            overlapped_latency(&sa2, &sb, &ready2)
        };
        assert!(
            aligned.saving > res.saving,
            "aligned {aligned:?} should beat mismatched {res:?}"
        );
    }

    #[test]
    fn ready_times_monotone_bounds() {
        // Ready cycles never exceed producer compute end + per-step move.
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(4, 2, 1, 8);
        let mb = simple_mapping(2, 2, 2, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let ready = AnalyticalOverlap::default().ready_times(&pair);
        let bound = sa.compute_cycles + sa.movement_cycles;
        for &(_, r) in &ready.probes {
            assert!(r <= bound, "ready {r} > bound {bound}");
        }
    }

    #[test]
    fn overlapped_latency_bounds() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(2, 4, 1, 8);
        let mb = simple_mapping(4, 2, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let ready = AnalyticalOverlap::default().ready_times(&pair);
        let res = overlapped_latency(&sa, &sb, &ready);
        // Never better than the consumer running entirely in parallel,
        // never worse than sequential.
        assert!(res.overlapped_end >= sb.latency_cycles);
        assert!(res.overlapped_end <= sa.latency_cycles + sb.latency_cycles);
        assert_eq!(
            res.saving + res.overlapped_end,
            sa.latency_cycles + sb.latency_cycles
        );
    }

    #[test]
    fn fc_consumer_boxes_cover_flattened_range() {
        let producer = Layer::conv("c", 1, 4, 8, 4, 4, 3, 3, 1, 1);
        let fc = Layer::fc("fc", 1, 10, 4 * 4 * 4);
        let mp = Mapping::new(vec![
            vec![],
            vec![],
            vec![Loop::temporal(Dim::K, 4), Loop::temporal(Dim::P, 4)],
            vec![
                Loop::spatial(Dim::Q, 4),
                Loop::temporal(Dim::C, 8),
                Loop::temporal(Dim::R, 3),
                Loop::temporal(Dim::S, 3),
            ],
        ]);
        let mc = Mapping::new(vec![
            vec![],
            vec![],
            vec![Loop::temporal(Dim::C, 16)],
            vec![Loop::spatial(Dim::K, 10), Loop::temporal(Dim::C, 4)],
        ]);
        let arch = Arch::dram_pim_small();
        let sp = eval(&arch, &producer, &mp);
        let sc = eval(&arch, &fc, &mc);
        let pair = LayerPair::new((&producer, &mp, &sp), (&fc, &mc, &sc));
        // Consumer step 0 consumes C [0,4) = flat k=0, rows 0..1 (q 0..4).
        let boxes = pair.step_input_boxes(0);
        let covered: u64 = boxes.iter().map(|b| b.k.len() * b.p.len() * b.q.len()).sum();
        assert_eq!(covered, 4);
        // Last step consumes the final flat segment.
        let ana = AnalyticalOverlap::default().ready_times(&pair);
        let exh = ExhaustiveOverlap::default().ready_times(&pair);
        assert_eq!(ana.probes, exh.probes);
    }

    #[test]
    fn engines_agree_on_batched_producer() {
        // Regression: a temporal batch (N) loop replays every output block
        // once per batch digit. The exhaustive oracle's latest-intersecting
        // step lands on the final replay; the analytical engine must charge
        // the same completion offset.
        let arch = Arch::dram_pim_small();
        let la = Layer::conv("a", 2, 8, 8, 8, 8, 3, 3, 1, 1);
        let lb = Layer::conv("b", 1, 8, 8, 8, 8, 3, 3, 1, 1);
        let ma = Mapping::new(vec![
            vec![],
            vec![],
            vec![Loop::temporal(Dim::N, 2), Loop::temporal(Dim::P, 8)],
            vec![
                Loop::spatial(Dim::K, 8),
                Loop::spatial(Dim::Q, 8),
                Loop::temporal(Dim::C, 8),
                Loop::temporal(Dim::R, 3),
                Loop::temporal(Dim::S, 3),
            ],
        ]);
        let mb = simple_mapping(1, 8, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let ana = AnalyticalOverlap::default().ready_times(&pair);
        let exh = ExhaustiveOverlap::default().ready_times(&pair);
        assert_eq!(ana.probes, exh.probes);
        // Every consumer step depends on the *second* batch pass: no probe
        // may be ready before step 8 of the producer finishes.
        let floor = sa.step_finish_cycle(8);
        for &(_, r) in &ana.probes {
            assert!(r >= floor, "ready {r} ignores the batch replay (floor {floor})");
        }
    }

    #[test]
    fn cache_returns_identical_ready_times() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(4, 2, 1, 8);
        let mb = simple_mapping(2, 4, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let cfg = OverlapConfig::default();
        let cache = OverlapCache::new();
        let key = pair_cache_key(&pair, 0, cfg.max_probe_steps);
        let direct = AnalyticalOverlap::new(cfg.clone()).ready_times(&pair);
        let first = cache.get_or_compute(key, || {
            AnalyticalOverlap::new(cfg.clone()).ready_times(&pair)
        });
        let second = cache.get_or_compute(key, || {
            panic!("second lookup must be a cache hit")
        });
        assert_eq!(first.probes, direct.probes);
        assert_eq!(second.probes, direct.probes);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn cache_capacity_bounds_insertions_without_changing_results() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(4, 2, 1, 8);
        let mb = simple_mapping(2, 4, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let direct = AnalyticalOverlap::default().ready_times(&pair);
        // Zero-capacity cache: pass-through, never stores, same values.
        let cache = OverlapCache::with_shard_cap(0);
        for _ in 0..3 {
            let got = cache.get_or_compute(pair_cache_key(&pair, 0, 2048), || {
                AnalyticalOverlap::default().ready_times(&pair)
            });
            assert_eq!(got.probes, direct.probes);
        }
        assert_eq!(cache.len(), 0, "capacity 0 must store nothing");
        assert_eq!(cache.misses(), 3);
    }

    #[test]
    fn cache_key_separates_pairs_probes_and_engines() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(4, 2, 1, 8);
        let ma2 = simple_mapping(2, 4, 1, 8);
        let mb = simple_mapping(2, 4, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sa2 = eval(&arch, &la, &ma2);
        let sb = eval(&arch, &lb, &mb);
        let p1 = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let p2 = LayerPair::new((&la, &ma2, &sa2), (&lb, &mb, &sb));
        let k1 = pair_cache_key(&p1, 0, 2048);
        let k2 = pair_cache_key(&p2, 0, 2048);
        assert_ne!(k1, k2, "different producer mappings must not share entries");
        assert_ne!(k1, pair_cache_key(&p1, 1, 2048), "engine tag must separate");
        assert_ne!(k1, pair_cache_key(&p1, 0, 64), "probe budget must separate");
        // Swapping roles must not alias.
        let swapped = LayerPair::new((&lb, &mb, &sb), (&la, &ma, &sa));
        assert_ne!(k1, pair_cache_key(&swapped, 0, 2048));
    }

    #[test]
    fn transform_table_memoizes_per_job_ready_queries() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(4, 2, 1, 8);
        let mb = simple_mapping(2, 4, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let tcfg = crate::transform::TransformConfig::default();
        let direct = crate::transform::transform_ready_jobs(&pair, &tcfg);
        let cache = OverlapCache::new();
        let key = transform_cache_key(&pair, tcfg.max_probe_jobs);
        let first = cache.transform_get_or_compute(key, || {
            crate::transform::transform_ready_jobs(&pair, &tcfg)
        });
        let second = cache.transform_get_or_compute(key, || panic!("second lookup must be a hit"));
        assert_eq!(*first, direct);
        assert_eq!(*second, direct);
        let stats = cache.stats();
        assert_eq!(stats.transform_hits, 1);
        assert_eq!(stats.transform_misses, 1);
        // The two tables are independent: no ready-times traffic happened.
        assert_eq!(stats.ready_hits + stats.ready_misses, 0);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
    }

    #[test]
    fn transform_key_separates_pairs_and_probe_budgets() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(4, 2, 1, 8);
        let ma2 = simple_mapping(2, 4, 1, 8);
        let mb = simple_mapping(2, 4, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sa2 = eval(&arch, &la, &ma2);
        let sb = eval(&arch, &lb, &mb);
        let p1 = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let p2 = LayerPair::new((&la, &ma2, &sa2), (&lb, &mb, &sb));
        let k1 = transform_cache_key(&p1, 2048);
        assert_ne!(k1, transform_cache_key(&p2, 2048), "producer mapping must separate");
        assert_ne!(k1, transform_cache_key(&p1, 64), "job-probe budget must separate");
        let swapped = LayerPair::new((&lb, &mb, &sb), (&la, &ma, &sa));
        assert_ne!(k1, transform_cache_key(&swapped, 2048), "roles must not alias");
    }

    #[test]
    fn merge_ready_times_takes_predecessor_max() {
        let a = ReadyTimes { probes: vec![(0, 10), (4, 50), (7, 0)], total_steps: 8 };
        let b = ReadyTimes { probes: vec![(0, 30), (4, 20), (7, 0)], total_steps: 8 };
        // Single part with zero offset: identity.
        let solo = merge_ready_times(&[(0, &a)]);
        assert_eq!(solo.probes, a.probes);
        assert_eq!(solo.total_steps, 8);
        // Two parts with offsets: per-probe max of offset + ready, with
        // padding-only probes (ready 0) contributing nothing.
        let merged = merge_ready_times(&[(100, &a), (0, &b)]);
        assert_eq!(merged.probes, vec![(0, 110), (4, 150), (7, 0)]);
    }

    #[test]
    fn overlapped_latency_at_matches_pairwise_form() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(4, 2, 1, 8);
        let mb = simple_mapping(2, 4, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let ready = AnalyticalOverlap::default().ready_times(&pair);
        let pairwise = overlapped_latency(&sa, &sb, &ready);
        let at = overlapped_latency_at(sa.latency_cycles, &sb, &ready);
        assert_eq!(pairwise, at);
        // A later producer end leaves the absolute end alone but shrinks
        // the added latency.
        let later = overlapped_latency_at(sa.latency_cycles + 1000, &sb, &ready);
        assert_eq!(later.overlapped_end, at.overlapped_end);
        assert_eq!(later.added_latency, at.added_latency.saturating_sub(1000));
    }

    #[test]
    fn merged_keys_never_alias_pairwise_keys() {
        let arch = Arch::dram_pim_small();
        let (la, lb) = conv_pair();
        let ma = simple_mapping(4, 2, 1, 8);
        let mb = simple_mapping(2, 4, 1, 8);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let plain = pair_cache_key(&pair, 0, 2048);
        assert_eq!(plain.pred_set, 0);
        let merged1 = merged_pair_cache_key(&[(0, &pair)], 0, 2048);
        assert_ne!(plain, merged1, "merged singleton must not alias the plain pair");
        let merged2 = merged_pair_cache_key(&[(0, &pair), (7, &pair)], 0, 2048);
        assert_eq!(merged2.pred_set, 2);
        assert_ne!(merged1, merged2);
        // Offsets are part of the fingerprint.
        let shifted = merged_pair_cache_key(&[(1, &pair)], 0, 2048);
        assert_ne!(merged1, shifted);
        // The transform twin follows the same rules.
        let tplain = transform_cache_key(&pair, 2048);
        assert_eq!(tplain.pred_set, 0);
        let tmerged = merged_transform_cache_key(&[(0, &pair)], 2048);
        assert_ne!(tplain, tmerged);
    }

    #[test]
    fn elementwise_consumer_ready_matches_exhaustive() {
        // Residual join: producer conv feeding an elementwise add with the
        // channel-identity input rule.
        let arch = Arch::dram_pim_small();
        let la = Layer::conv("a", 1, 8, 8, 8, 8, 3, 3, 1, 1);
        let lb = Layer::elementwise("add", 1, 8, 8, 8);
        let ma = simple_mapping(4, 2, 1, 8);
        let mb = Mapping::new(vec![
            vec![],
            vec![],
            vec![Loop::temporal(Dim::K, 2), Loop::temporal(Dim::P, 8)],
            vec![Loop::spatial(Dim::K, 4), Loop::spatial(Dim::Q, 8)],
        ]);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let ana = AnalyticalOverlap::default().ready_times(&pair);
        let exh = ExhaustiveOverlap::default().ready_times(&pair);
        assert_eq!(ana.probes, exh.probes);
        // The join's K digit selects the producer channel: early K steps
        // must not wait for the full producer.
        assert!(ana.probes[0].1 < sa.latency_cycles, "{ana:?}");
    }

    #[test]
    fn probe_indices_cover_endpoints() {
        assert_eq!(probe_indices(5, 8), vec![0, 1, 2, 3, 4]);
        let p = probe_indices(1000, 10);
        assert!(p.len() <= 11);
        assert_eq!(p[0], 0);
        assert_eq!(*p.last().unwrap(), 999);
    }

    #[test]
    fn pooled_pair_ready_before_producer_end() {
        // Producer with pool_after=2 feeding a consumer at half spatial res.
        let la = Layer::conv("a", 1, 8, 8, 8, 8, 3, 3, 1, 1).with_pool(2);
        let lb = Layer::conv("b", 1, 8, 8, 4, 4, 3, 3, 1, 1);
        let arch = Arch::dram_pim_small();
        let ma = simple_mapping(1, 8, 1, 8);
        let mb = Mapping::new(vec![
            vec![],
            vec![],
            vec![Loop::temporal(Dim::P, 4)],
            vec![
                Loop::spatial(Dim::K, 8),
                Loop::spatial(Dim::Q, 4),
                Loop::temporal(Dim::C, 8),
                Loop::temporal(Dim::R, 3),
                Loop::temporal(Dim::S, 3),
            ],
        ]);
        let sa = eval(&arch, &la, &ma);
        let sb = eval(&arch, &lb, &mb);
        let pair = LayerPair::new((&la, &ma, &sa), (&lb, &mb, &sb));
        let ana = AnalyticalOverlap::default().ready_times(&pair);
        let exh = ExhaustiveOverlap::default().ready_times(&pair);
        assert_eq!(ana.probes, exh.probes);
        // The first consumer row depends on producer rows 0..4-ish, not all.
        assert!(ana.probes[0].1 < sa.latency_cycles);
    }
}
