//! The typed request/response API — the single wire format.
//!
//! Every programmatic entry point speaks the same versioned JSON schema:
//! `repro serve` (the mapping-as-a-service server), `repro request` (its
//! client), and `repro search --json` (one-shot CLI emission). The schema
//! is deliberately tiny and std-only — [`crate::report::Json`] both ways,
//! no serde — and versioned with a top-level `"v": 1` so later PRs can
//! evolve it without breaking recorded responses.
//!
//! Shapes (documented in `rust/ARCHITECTURE.md` §11):
//!
//! * [`SearchRequest`] — what to search: a network (zoo name or inline
//!   YAML), an architecture (preset name or inline YAML), metric, a
//!   deterministic evaluation budget, engine, strategy, seed.
//! * [`SearchResponse`] — a deterministic `plan` section (totals,
//!   per-layer mappings, per-edge overlap) that is **byte-identical** for
//!   identical plan keys, plus a nondeterministic `server` section
//!   (timings, cache/pool counters) that callers must ignore when
//!   comparing plans.
//! * [`ApiError`] — a closed set of stable error codes
//!   ([`ApiErrorKind`]) mapped onto HTTP statuses and the CLI's exit-2
//!   convention.
//!
//! Determinism is the contract: a request's plan is a pure function of
//! its [`plan_key`] — `(arch fingerprint, network fingerprint, metric,
//! budget, algo, strategy, seed, refine)` — which is why requests only
//! carry [`Budget::Evaluations`]-style budgets (wall-clock budgets are
//! timing-dependent and would break `same key ⇒ same plan`).

use crate::arch::{arch_from_yaml, Arch};
use crate::optimize::SearchAlgo;
use crate::overlap::CacheStats;
use crate::report::Json;
use crate::search::{
    MapperConfig, Metric, MiddleHeuristic, NetworkPlan, NetworkSearch, SearchStrategy,
};
use crate::util::Fnv64;
use crate::workload::{parser, zoo, Network, NetworkGraph};

/// Wire-format schema version emitted and required by this build.
pub const API_VERSION: u64 = 1;

/// Stable machine-readable error codes — a *closed* enum: new failure
/// modes must map onto one of these rather than inventing ad-hoc codes,
/// so clients can switch on them forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ApiErrorKind {
    /// Malformed request: bad JSON, a missing or ill-typed field, an
    /// unknown enum value, or a config that fails builder validation.
    BadRequest,
    /// The named architecture or network preset does not exist.
    UnknownPreset,
    /// The network parsed but failed semantic validation (channel
    /// mismatches, cycles, ambiguous sinks, ...).
    InvalidNetwork,
    /// Admission control turned the request away; retry later.
    Busy,
    /// The search itself failed — a server-side bug, never the client's
    /// fault.
    Internal,
}

impl ApiErrorKind {
    /// The stable wire code (pinned by `tests/cli_errors.rs`).
    pub fn code(self) -> &'static str {
        match self {
            ApiErrorKind::BadRequest => "bad_request",
            ApiErrorKind::UnknownPreset => "unknown_preset",
            ApiErrorKind::InvalidNetwork => "invalid_network",
            ApiErrorKind::Busy => "busy",
            ApiErrorKind::Internal => "internal",
        }
    }

    /// The HTTP status the serve layer maps this code onto.
    pub fn http_status(self) -> (u16, &'static str) {
        match self {
            ApiErrorKind::BadRequest => (400, "Bad Request"),
            ApiErrorKind::UnknownPreset => (404, "Not Found"),
            ApiErrorKind::InvalidNetwork => (422, "Unprocessable Entity"),
            ApiErrorKind::Busy => (429, "Too Many Requests"),
            ApiErrorKind::Internal => (500, "Internal Server Error"),
        }
    }

    /// Inverse of [`ApiErrorKind::code`].
    pub fn from_code(code: &str) -> Option<ApiErrorKind> {
        match code {
            "bad_request" => Some(ApiErrorKind::BadRequest),
            "unknown_preset" => Some(ApiErrorKind::UnknownPreset),
            "invalid_network" => Some(ApiErrorKind::InvalidNetwork),
            "busy" => Some(ApiErrorKind::Busy),
            "internal" => Some(ApiErrorKind::Internal),
            _ => None,
        }
    }
}

/// A typed API error: a stable code plus a human-readable message.
///
/// Displays as `code: message`, which is what the CLI prints (behind its
/// `repro: error: ` prefix) before exiting 2, and what the server wraps
/// as `{"v":1,"error":{"code":...,"message":...}}`.
#[derive(Debug, Clone)]
pub struct ApiError {
    pub kind: ApiErrorKind,
    pub message: String,
}

impl ApiError {
    pub fn new(kind: ApiErrorKind, message: impl Into<String>) -> ApiError {
        ApiError { kind, message: message.into() }
    }

    pub fn bad_request(message: impl Into<String>) -> ApiError {
        ApiError::new(ApiErrorKind::BadRequest, message)
    }

    pub fn unknown_preset(message: impl Into<String>) -> ApiError {
        ApiError::new(ApiErrorKind::UnknownPreset, message)
    }

    pub fn invalid_network(message: impl Into<String>) -> ApiError {
        ApiError::new(ApiErrorKind::InvalidNetwork, message)
    }

    pub fn busy(message: impl Into<String>) -> ApiError {
        ApiError::new(ApiErrorKind::Busy, message)
    }

    pub fn internal(message: impl Into<String>) -> ApiError {
        ApiError::new(ApiErrorKind::Internal, message)
    }

    /// The wire shape: `{"v":1,"error":{"code":...,"message":...}}`.
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("v".into(), Json::num(API_VERSION as u32)),
            (
                "error".into(),
                Json::Obj(vec![
                    ("code".into(), Json::str(self.kind.code())),
                    ("message".into(), Json::str(self.message.clone())),
                ]),
            ),
        ])
    }

    pub fn render(&self) -> String {
        self.to_json().render()
    }

    /// Re-hydrate a wire error (the `repro request` client uses this to
    /// print the server's code + message verbatim).
    pub fn parse(text: &str) -> Option<ApiError> {
        let doc = Json::parse(text).ok()?;
        let err = doc.get("error")?;
        let kind = ApiErrorKind::from_code(err.get("code")?.as_str()?)?;
        let message = err.get("message")?.as_str()?.to_string();
        Some(ApiError { kind, message })
    }
}

impl std::fmt::Display for ApiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}: {}", self.kind.code(), self.message)
    }
}

/// A network or architecture reference: a preset name, or inline YAML.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Source {
    /// A zoo / preset name (`"resnet18"`, `"dram"`).
    Name(String),
    /// Inline YAML text (`{"yaml": "..."}` on the wire).
    Yaml(String),
}

impl Source {
    fn to_json(&self) -> Json {
        match self {
            Source::Name(n) => Json::str(n.clone()),
            Source::Yaml(y) => Json::Obj(vec![("yaml".into(), Json::str(y.clone()))]),
        }
    }

    fn from_json(field: &str, j: &Json) -> Result<Source, ApiError> {
        if let Some(name) = j.as_str() {
            return Ok(Source::Name(name.to_string()));
        }
        if let Some(yaml) = j.get("yaml").and_then(Json::as_str) {
            return Ok(Source::Yaml(yaml.to_string()));
        }
        Err(ApiError::bad_request(format!(
            "`{field}` must be a preset name string or {{\"yaml\": \"...\"}}"
        )))
    }
}

/// A resolved `network` reference: a layer chain or a computation graph.
#[derive(Debug, Clone)]
pub enum RequestWorkload {
    Chain(Network),
    Graph(NetworkGraph),
}

impl RequestWorkload {
    pub fn name(&self) -> &str {
        match self {
            RequestWorkload::Chain(n) => &n.name,
            RequestWorkload::Graph(g) => &g.name,
        }
    }

    /// Shape fingerprint, tagged by representation: a chain and its
    /// graph promotion run different sweeps, so they must never share a
    /// plan-cache key.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv64::new();
        match self {
            RequestWorkload::Chain(n) => {
                h.write(1);
                h.write(n.fingerprint());
            }
            RequestWorkload::Graph(g) => {
                h.write(2);
                h.write(g.fingerprint());
            }
        }
        h.finish()
    }
}

/// A versioned search request — everything that determines the plan,
/// and nothing that doesn't (no thread counts, no cache toggles: those
/// are server-side serving knobs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SearchRequest {
    /// What to map: zoo chain/graph preset name, or inline YAML (chain
    /// or graph syntax — auto-detected).
    pub network: Source,
    /// Target architecture: `dram`/`reram`/`small`, or inline YAML.
    pub arch: Source,
    /// Which metric the per-layer searches optimize.
    pub metric: Metric,
    /// Deterministic per-layer draw budget ([`crate::search::Budget::Evaluations`]).
    /// Wall-clock budget variants are deliberately not expressible here:
    /// they would break `same key ⇒ same plan`.
    pub budget_evals: usize,
    /// Search engine.
    pub algo: SearchAlgo,
    /// Whole-network traversal strategy.
    pub strategy: SearchStrategy,
    /// PRNG seed.
    pub seed: u64,
    /// Coordinate-descent refinement sweeps.
    pub refine_passes: usize,
    /// Replay the winning plan through the validation simulator before
    /// responding (server-side assertion; does not change the plan).
    pub verify: bool,
    /// Record search-phase spans ([`crate::obs::Recorder`]) and return
    /// the Chrome-trace profile in the response's nondeterministic
    /// `server` section. Observationally transparent — never
    /// plan-affecting, never part of [`plan_key`].
    pub profile: bool,
}

impl Default for SearchRequest {
    fn default() -> Self {
        let cfg = MapperConfig::default();
        SearchRequest {
            network: Source::Name("resnet18".into()),
            arch: Source::Name("dram".into()),
            metric: Metric::Transform,
            budget_evals: 100,
            algo: SearchAlgo::Random,
            strategy: SearchStrategy::Forward,
            seed: cfg.seed,
            refine_passes: cfg.refine_passes,
            verify: false,
            profile: false,
        }
    }
}

impl SearchRequest {
    /// Serialize to the versioned wire shape. `profile` is emitted only
    /// when set, so pre-profiler request documents render byte-identically.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("v".into(), Json::num(API_VERSION as u32)),
            ("network".into(), self.network.to_json()),
            ("arch".into(), self.arch.to_json()),
            ("metric".into(), Json::str(metric_tag(self.metric))),
            ("budget".into(), Json::Num(self.budget_evals as f64)),
            ("algo".into(), Json::str(self.algo.name())),
            ("strategy".into(), Json::str(strategy_tag(self.strategy))),
            ("seed".into(), Json::Num(self.seed as f64)),
            ("refine".into(), Json::Num(self.refine_passes as f64)),
            ("verify".into(), Json::Bool(self.verify)),
        ];
        if self.profile {
            fields.push(("profile".into(), Json::Bool(true)));
        }
        Json::Obj(fields)
    }

    pub fn render(&self) -> String {
        self.to_json().render()
    }

    /// Parse a request document. Every field except `network` is
    /// optional and defaults as in [`SearchRequest::default`]; unknown
    /// versions and ill-typed fields are [`ApiErrorKind::BadRequest`].
    pub fn parse(text: &str) -> Result<SearchRequest, ApiError> {
        let doc = Json::parse(text)
            .map_err(|e| ApiError::bad_request(format!("invalid JSON: {e}")))?;
        SearchRequest::from_json(&doc)
    }

    pub fn from_json(doc: &Json) -> Result<SearchRequest, ApiError> {
        if !matches!(doc, Json::Obj(_)) {
            return Err(ApiError::bad_request("request must be a JSON object"));
        }
        if let Some(v) = doc.get("v") {
            let v = v
                .as_u64()
                .ok_or_else(|| ApiError::bad_request("`v` must be a whole number"))?;
            if v != API_VERSION {
                return Err(ApiError::bad_request(format!(
                    "unsupported schema version {v} (this build speaks v{API_VERSION})"
                )));
            }
        }
        let defaults = SearchRequest::default();
        let network = doc
            .get("network")
            .ok_or_else(|| ApiError::bad_request("missing required field `network`"))
            .and_then(|j| Source::from_json("network", j))?;
        let arch = match doc.get("arch") {
            Some(j) => Source::from_json("arch", j)?,
            None => defaults.arch,
        };
        let metric = match doc.get("metric") {
            Some(j) => {
                let tag = j
                    .as_str()
                    .ok_or_else(|| ApiError::bad_request("`metric` must be a string"))?;
                parse_metric(tag).ok_or_else(|| {
                    ApiError::bad_request(format!(
                        "unknown metric `{tag}` (valid: seq|overlap|transform)"
                    ))
                })?
            }
            None => defaults.metric,
        };
        let algo = match doc.get("algo") {
            Some(j) => {
                let tag = j
                    .as_str()
                    .ok_or_else(|| ApiError::bad_request("`algo` must be a string"))?;
                SearchAlgo::parse(tag).ok_or_else(|| {
                    ApiError::bad_request(format!(
                        "unknown algo `{tag}` (valid: random|ga|sa|hill)"
                    ))
                })?
            }
            None => defaults.algo,
        };
        let strategy = match doc.get("strategy") {
            Some(j) => {
                let tag = j
                    .as_str()
                    .ok_or_else(|| ApiError::bad_request("`strategy` must be a string"))?;
                parse_strategy(tag).ok_or_else(|| {
                    ApiError::bad_request(format!(
                        "unknown strategy `{tag}` (valid: forward|backward|middle|middle2)"
                    ))
                })?
            }
            None => defaults.strategy,
        };
        let u64_field = |name: &str, default: u64| -> Result<u64, ApiError> {
            match doc.get(name) {
                Some(j) => j.as_u64().ok_or_else(|| {
                    ApiError::bad_request(format!("`{name}` must be a non-negative whole number"))
                }),
                None => Ok(default),
            }
        };
        let budget_evals = u64_field("budget", defaults.budget_evals as u64)? as usize;
        if budget_evals == 0 {
            return Err(ApiError::bad_request("`budget` must be >= 1"));
        }
        let seed = u64_field("seed", defaults.seed)?;
        let refine_passes = u64_field("refine", defaults.refine_passes as u64)? as usize;
        let verify = match doc.get("verify") {
            Some(j) => j
                .as_bool()
                .ok_or_else(|| ApiError::bad_request("`verify` must be a boolean"))?,
            None => defaults.verify,
        };
        let profile = match doc.get("profile") {
            Some(j) => j
                .as_bool()
                .ok_or_else(|| ApiError::bad_request("`profile` must be a boolean"))?,
            None => defaults.profile,
        };
        Ok(SearchRequest {
            network,
            arch,
            metric,
            budget_evals,
            algo,
            strategy,
            seed,
            refine_passes,
            verify,
            profile,
        })
    }

    /// Resolve the `arch` reference. Unknown preset names are
    /// [`ApiErrorKind::UnknownPreset`]; YAML that fails to parse is
    /// [`ApiErrorKind::BadRequest`].
    pub fn resolve_arch(&self) -> Result<Arch, ApiError> {
        match &self.arch {
            Source::Name(name) => match name.as_str() {
                "dram" => Ok(Arch::dram_pim()),
                "reram" => Ok(Arch::reram_pim()),
                "small" => Ok(Arch::dram_pim_small()),
                other => Err(ApiError::unknown_preset(format!(
                    "unknown arch preset `{other}` (valid: dram|reram|small)"
                ))),
            },
            Source::Yaml(text) => arch_from_yaml(text)
                .map_err(|e| ApiError::bad_request(format!("parsing arch YAML: {e}"))),
        }
    }

    /// Resolve the `network` reference. Unknown preset names are
    /// [`ApiErrorKind::UnknownPreset`]; YAML that parses but fails
    /// validation is [`ApiErrorKind::InvalidNetwork`].
    pub fn resolve_workload(&self) -> Result<RequestWorkload, ApiError> {
        match &self.network {
            Source::Name(name) => {
                if let Some(g) = zoo::graph_by_name(name) {
                    return Ok(RequestWorkload::Graph(g));
                }
                if let Some(net) = zoo::by_name(name) {
                    return Ok(RequestWorkload::Chain(net));
                }
                let chains: Vec<&str> = zoo::all().iter().map(|(n, _)| *n).collect();
                let graphs: Vec<&str> = zoo::graphs().iter().map(|(n, _)| *n).collect();
                Err(ApiError::unknown_preset(format!(
                    "unknown network preset `{name}` (chains: {}; graphs: {})",
                    chains.join("|"),
                    graphs.join("|")
                )))
            }
            Source::Yaml(text) => {
                if parser::yaml_is_graph(text) {
                    parser::graph_from_yaml(text)
                        .map(RequestWorkload::Graph)
                        .map_err(|e| ApiError::invalid_network(format!("network YAML: {e}")))
                } else {
                    parser::network_from_yaml(text)
                        .map(RequestWorkload::Chain)
                        .map_err(|e| ApiError::invalid_network(format!("network YAML: {e}")))
                }
            }
        }
    }

    /// Build the validated [`MapperConfig`] this request implies.
    /// `threads` is a serving knob, not a request field — plans are
    /// bit-identical at any thread count for evaluation budgets.
    pub fn mapper_config(&self, threads: usize) -> Result<MapperConfig, ApiError> {
        MapperConfig::builder()
            .budget_evals(self.budget_evals)
            .algo(self.algo)
            .seed(self.seed)
            .refine_passes(self.refine_passes)
            .verify(self.verify)
            .threads(threads)
            .build()
            .map_err(|e| ApiError::bad_request(e.to_string()))
    }
}

/// The deterministic plan-cache key: same key ⇒ bit-identical plan.
/// Hashes the resolved shapes (arch + workload fingerprints) rather than
/// the request text, so `"resnet18"` and its exported YAML share an
/// entry, while a chain and its graph promotion do not.
pub fn plan_key(req: &SearchRequest, arch: &Arch, workload: &RequestWorkload) -> u64 {
    let mut h = Fnv64::new();
    h.write(API_VERSION);
    h.write(arch.fingerprint());
    h.write(workload.fingerprint());
    h.write(metric_ordinal(req.metric));
    h.write(req.budget_evals as u64);
    h.write(algo_ordinal(req.algo));
    h.write(strategy_ordinal(req.strategy));
    h.write(req.seed);
    h.write(req.refine_passes as u64);
    h.finish()
}

/// Run a resolved request's search on an existing searcher.
pub fn run_workload(
    search: &NetworkSearch<'_>,
    workload: &RequestWorkload,
    metric: Metric,
) -> NetworkPlan {
    match workload {
        RequestWorkload::Chain(net) => search.run(net, metric),
        RequestWorkload::Graph(g) => search.run_graph(g, metric),
    }
}

/// A versioned search response: a deterministic `plan` section plus a
/// nondeterministic `server` section. Renders as
/// `{"v":1,"plan":{...},"server":{...}}`; plan bytes are the determinism
/// contract, the server section carries timings and cache counters that
/// differ run to run.
#[derive(Debug, Clone)]
pub struct SearchResponse {
    /// Rendered deterministic plan payload (see [`plan_to_json`]) —
    /// kept as the exact byte string so disk-cached plans round-trip
    /// without any float re-rendering.
    pub plan_raw: String,
    /// Serving metadata (timings, cache outcome, pool stats).
    pub server: Json,
}

impl SearchResponse {
    pub fn new(plan: &Json, server: Json) -> SearchResponse {
        SearchResponse { plan_raw: plan.render(), server }
    }

    /// Assemble from an already-rendered plan (the disk-cache hit path:
    /// the stored bytes are spliced in verbatim, guaranteeing
    /// byte-identity across restarts).
    pub fn from_raw(plan_raw: String, server: Json) -> SearchResponse {
        SearchResponse { plan_raw, server }
    }

    pub fn render(&self) -> String {
        format!(
            "{{\"v\":{API_VERSION},\"plan\":{},\"server\":{}}}",
            self.plan_raw,
            self.server.render()
        )
    }

    /// Parse a response document (client side).
    pub fn parse(text: &str) -> Result<SearchResponse, ApiError> {
        let doc = Json::parse(text)
            .map_err(|e| ApiError::bad_request(format!("invalid response JSON: {e}")))?;
        let plan = doc
            .get("plan")
            .ok_or_else(|| ApiError::bad_request("response has no `plan` section"))?;
        let server = doc.get("server").cloned().unwrap_or(Json::Obj(vec![]));
        Ok(SearchResponse { plan_raw: plan.render(), server })
    }

    /// Slice the raw plan bytes out of a rendered response without
    /// re-parsing — the byte-identity comparisons in the tests (and the
    /// disk cache) use this so float formatting never round-trips.
    pub fn extract_plan_raw(text: &str) -> Option<&str> {
        let prefix = format!("{{\"v\":{API_VERSION},\"plan\":");
        let rest = text.strip_prefix(prefix.as_str())?;
        let end = rest.rfind(",\"server\":")?;
        Some(&rest[..end])
    }
}

/// Serialize the deterministic parts of a [`NetworkPlan`]: totals,
/// per-layer mappings and contributions, per-edge pairwise overlap.
/// Wall-clock and cache counters are deliberately *excluded* — they vary
/// run to run and belong in the response's `server` section.
pub fn plan_to_json(plan: &NetworkPlan, arch: &Arch) -> Json {
    let layers: Vec<Json> = plan
        .layers
        .iter()
        .map(|l| {
            let overlap = match &l.overlap {
                Some(o) => Json::Obj(vec![
                    ("added".into(), Json::Num(o.added_latency as f64)),
                    ("saving".into(), Json::Num(o.saving as f64)),
                    ("fraction".into(), Json::Num(o.overlap_fraction)),
                ]),
                None => Json::Null,
            };
            let transform = match &l.transform {
                Some(t) => Json::Obj(vec![
                    ("added".into(), Json::Num(t.added_latency as f64)),
                    ("saving".into(), Json::Num(t.saving as f64)),
                    ("moved_fraction".into(), Json::Num(t.moved_fraction)),
                    ("penalty".into(), Json::Num(t.penalty_cycles as f64)),
                ]),
                None => Json::Null,
            };
            Json::Obj(vec![
                ("index".into(), Json::Num(l.layer_index as f64)),
                ("name".into(), Json::str(l.name.clone())),
                ("mapping".into(), Json::str(l.mapping.render(arch))),
                (
                    "mapping_fingerprint".into(),
                    Json::str(format!("{:016x}", l.mapping.fingerprint())),
                ),
                ("latency".into(), Json::Num(l.stats.latency_cycles as f64)),
                ("energy_pj".into(), Json::Num(l.stats.energy_pj)),
                ("utilization".into(), Json::Num(l.stats.utilization)),
                ("sequential".into(), Json::Num(l.sequential_contribution() as f64)),
                ("overlapped".into(), Json::Num(l.overlapped_contribution() as f64)),
                ("transformed".into(), Json::Num(l.transformed_contribution() as f64)),
                ("overlap".into(), overlap),
                ("transform".into(), transform),
            ])
        })
        .collect();
    let edges: Vec<Json> = plan
        .edge_overlaps
        .iter()
        .map(|e| {
            Json::Obj(vec![
                ("from".into(), Json::Num(e.from as f64)),
                ("to".into(), Json::Num(e.to as f64)),
                ("overlap_added".into(), Json::Num(e.overlap.added_latency as f64)),
                ("transform_added".into(), Json::Num(e.transform.added_latency as f64)),
                ("saving".into(), Json::Num(e.overlap.saving as f64)),
                ("fraction".into(), Json::Num(e.overlap.overlap_fraction)),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("network".into(), Json::str(plan.network.clone())),
        ("arch".into(), Json::str(arch.name.clone())),
        ("strategy".into(), Json::str(strategy_tag(plan.strategy))),
        ("metric".into(), Json::str(metric_tag(plan.metric))),
        ("total_sequential".into(), Json::Num(plan.total_sequential as f64)),
        ("total_overlapped".into(), Json::Num(plan.total_overlapped as f64)),
        ("total_transformed".into(), Json::Num(plan.total_transformed as f64)),
        ("mappings_evaluated".into(), Json::Num(plan.mappings_evaluated as f64)),
        ("layers".into(), Json::Arr(layers)),
        ("edges".into(), Json::Arr(edges)),
    ])
}

/// Serialize the full analysis-cache counters (server section), in the
/// one field order [`CacheStats::fields`] defines for every surface.
pub fn cache_stats_json(stats: &CacheStats) -> Json {
    Json::Obj(
        stats
            .fields()
            .iter()
            .map(|&(name, value)| (name.to_string(), Json::Num(value as f64)))
            .collect(),
    )
}

/// The API's lowercase metric tag (`seq|overlap|transform`).
pub fn metric_tag(metric: Metric) -> &'static str {
    match metric {
        Metric::Sequential => "seq",
        Metric::Overlap => "overlap",
        Metric::Transform => "transform",
    }
}

/// Inverse of [`metric_tag`] (also accepts `sequential`).
pub fn parse_metric(tag: &str) -> Option<Metric> {
    match tag {
        "seq" | "sequential" => Some(Metric::Sequential),
        "overlap" => Some(Metric::Overlap),
        "transform" => Some(Metric::Transform),
        _ => None,
    }
}

/// The API's lowercase strategy tag (`forward|backward|middle|middle2`).
pub fn strategy_tag(strategy: SearchStrategy) -> &'static str {
    match strategy {
        SearchStrategy::Forward => "forward",
        SearchStrategy::Backward => "backward",
        SearchStrategy::Middle(MiddleHeuristic::LargestOutput) => "middle",
        SearchStrategy::Middle(MiddleHeuristic::LargestOverall) => "middle2",
    }
}

/// Inverse of [`strategy_tag`].
pub fn parse_strategy(tag: &str) -> Option<SearchStrategy> {
    match tag {
        "forward" => Some(SearchStrategy::Forward),
        "backward" => Some(SearchStrategy::Backward),
        "middle" => Some(SearchStrategy::Middle(MiddleHeuristic::LargestOutput)),
        "middle2" => Some(SearchStrategy::Middle(MiddleHeuristic::LargestOverall)),
        _ => None,
    }
}

fn metric_ordinal(metric: Metric) -> u64 {
    match metric {
        Metric::Sequential => 0,
        Metric::Overlap => 1,
        Metric::Transform => 2,
    }
}

fn algo_ordinal(algo: SearchAlgo) -> u64 {
    match algo {
        SearchAlgo::Random => 0,
        SearchAlgo::Genetic => 1,
        SearchAlgo::Annealing => 2,
        SearchAlgo::HillClimb => 3,
    }
}

fn strategy_ordinal(strategy: SearchStrategy) -> u64 {
    match strategy {
        SearchStrategy::Forward => 0,
        SearchStrategy::Backward => 1,
        SearchStrategy::Middle(MiddleHeuristic::LargestOutput) => 2,
        SearchStrategy::Middle(MiddleHeuristic::LargestOverall) => 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrips_through_json() {
        let req = SearchRequest {
            network: Source::Name("tiny-cnn".into()),
            arch: Source::Name("small".into()),
            metric: Metric::Overlap,
            budget_evals: 12,
            algo: SearchAlgo::Genetic,
            strategy: SearchStrategy::Backward,
            seed: 7,
            refine_passes: 0,
            verify: true,
            profile: true,
        };
        let text = req.render();
        assert_eq!(SearchRequest::parse(&text).unwrap(), req);
        // `profile` is emitted only when set: an unprofiled request
        // renders exactly the pre-profiler wire bytes.
        let plain = SearchRequest { profile: false, ..req };
        assert!(!plain.render().contains("profile"));
        assert_eq!(SearchRequest::parse(&plain.render()).unwrap(), plain);
    }

    #[test]
    fn request_defaults_apply() {
        let req = SearchRequest::parse(r#"{"network":"tiny-cnn"}"#).unwrap();
        assert_eq!(req.metric, Metric::Transform);
        assert_eq!(req.budget_evals, 100);
        assert_eq!(req.algo, SearchAlgo::Random);
        assert_eq!(req.arch, Source::Name("dram".into()));
    }

    #[test]
    fn request_rejects_bad_fields() {
        for (text, want) in [
            ("{", "invalid JSON"),
            ("{}", "missing required field `network`"),
            (r#"{"v":2,"network":"tiny-cnn"}"#, "unsupported schema version"),
            (r#"{"network":"tiny-cnn","metric":"fast"}"#, "unknown metric"),
            (r#"{"network":"tiny-cnn","algo":"brute"}"#, "unknown algo"),
            (r#"{"network":"tiny-cnn","strategy":"up"}"#, "unknown strategy"),
            (r#"{"network":"tiny-cnn","budget":0}"#, "`budget` must be >= 1"),
            (r#"{"network":42}"#, "`network` must be"),
        ] {
            let err = SearchRequest::parse(text).unwrap_err();
            assert_eq!(err.kind, ApiErrorKind::BadRequest, "{text}");
            assert!(err.message.contains(want), "{text}: {}", err.message);
        }
    }

    #[test]
    fn resolution_maps_error_codes() {
        let mut req = SearchRequest { network: Source::Name("nope".into()), ..Default::default() };
        assert_eq!(req.resolve_workload().unwrap_err().kind, ApiErrorKind::UnknownPreset);
        req.arch = Source::Name("tpu".into());
        assert_eq!(req.resolve_arch().unwrap_err().kind, ApiErrorKind::UnknownPreset);
        req.network = Source::Yaml("layers:\n  - nonsense".into());
        assert_eq!(req.resolve_workload().unwrap_err().kind, ApiErrorKind::InvalidNetwork);
    }

    #[test]
    fn plan_key_tracks_plan_affecting_fields_only() {
        let req = SearchRequest {
            network: Source::Name("tiny-cnn".into()),
            arch: Source::Name("small".into()),
            ..Default::default()
        };
        let arch = req.resolve_arch().unwrap();
        let wl = req.resolve_workload().unwrap();
        let base = plan_key(&req, &arch, &wl);
        assert_eq!(base, plan_key(&req, &arch, &wl), "stable");
        let mut seeded = req.clone();
        seeded.seed += 1;
        assert_ne!(base, plan_key(&seeded, &arch, &wl));
        let mut verified = req.clone();
        verified.verify = true;
        assert_eq!(base, plan_key(&verified, &arch, &wl), "verify is not plan-affecting");
        let mut profiled = req.clone();
        profiled.profile = true;
        assert_eq!(base, plan_key(&profiled, &arch, &wl), "profile is not plan-affecting");
    }

    #[test]
    fn error_codes_are_stable() {
        let pairs = [
            (ApiErrorKind::BadRequest, "bad_request", 400),
            (ApiErrorKind::UnknownPreset, "unknown_preset", 404),
            (ApiErrorKind::InvalidNetwork, "invalid_network", 422),
            (ApiErrorKind::Busy, "busy", 429),
            (ApiErrorKind::Internal, "internal", 500),
        ];
        for (kind, code, status) in pairs {
            assert_eq!(kind.code(), code);
            assert_eq!(kind.http_status().0, status);
            assert_eq!(ApiErrorKind::from_code(code), Some(kind));
        }
        let err = ApiError::busy("1 request in flight");
        let wire = err.render();
        let back = ApiError::parse(&wire).unwrap();
        assert_eq!(back.kind, ApiErrorKind::Busy);
        assert_eq!(back.message, "1 request in flight");
    }

    #[test]
    fn response_plan_bytes_roundtrip() {
        let plan = Json::Obj(vec![("total".into(), Json::num(42u32))]);
        let server = Json::Obj(vec![("elapsed_us".into(), Json::num(7u32))]);
        let resp = SearchResponse::new(&plan, server);
        let text = resp.render();
        assert_eq!(SearchResponse::extract_plan_raw(&text), Some(r#"{"total":42}"#));
        let parsed = SearchResponse::parse(&text).unwrap();
        assert_eq!(parsed.plan_raw, plan.render());
    }
}
