//! The PIM performance model (paper §IV-C, Table I).
//!
//! Timeloop's native model only counts compute/read/write; PIM evaluation
//! needs the data movements of in-memory execution instead. Following the
//! paper, each MAC in a bank is modelled in three phases:
//!
//! 1. element-wise multiplication producing partial products — bit-serial,
//!    one `mul` PIM op per MAC (a 16-bit multiply = 16 sequential full
//!    additions; a full addition = `4n+1` AAP commands);
//! 2. read/write transposition moving operands/partials between row
//!    orientation and column lanes;
//! 3. serial additions reducing partial sums.
//!
//! Latency is charged per bank-level *temporal step*: all column lanes of
//! a bank execute in lock-step (row-parallel bit-serial, §III-A), so a step
//! costs `waves × macs_per_output × (mul + add)` plus intra-bank reduction,
//! where `waves` covers output tiles wider than the lane count. Data
//! movement adds (a) the producer→consumer output transfer over the
//! channel links and (b) partial-sum reduction movement when reduction
//! dimensions are split spatially. Energy follows Table I.

use crate::arch::Arch;
use crate::mapping::{nest_fingerprint, Dim, Loop, Mapping};
use crate::util::ceil_div;
use crate::workload::Layer;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Evaluation result for one (layer, mapping) pair.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerStats {
    /// End-to-end sequential latency of the layer (no overlap), cycles.
    pub latency_cycles: u64,
    /// Pure compute portion.
    pub compute_cycles: u64,
    /// Data-movement portion (inter-layer transfer + reductions).
    pub movement_cycles: u64,
    /// Latency of one bank-level temporal step, cycles.
    pub step_cycles: u64,
    /// Number of bank-level temporal steps.
    pub temporal_steps: u64,
    /// Compute instances (banks) the mapping occupies.
    pub banks_used: u64,
    /// Output elements computed per step per bank.
    pub outputs_per_step: u64,
    /// Total energy, picojoules.
    pub energy_pj: f64,
    /// Bank × lane occupancy in [0, 1] (padding waste included).
    pub utilization: f64,
}

impl LayerStats {
    /// Convert a bank-level step index (0-based) to the cycle at which that
    /// step *finishes*.
    #[inline]
    pub fn step_finish_cycle(&self, step: u64) -> u64 {
        (step + 1) * self.step_cycles
    }
}

/// Everything [`PerfModel::evaluate`] reads from one sub-nest, reduced to
/// commutative `u64` bound products — so per-nest results can be cached
/// and recombined without changing a single bit of the final stats.
#[derive(Debug, Clone, Copy)]
struct NestAgg {
    /// Product of all loop bounds per dimension (the interior tile when
    /// this is the interior nest).
    per_dim: [u64; 7],
    /// Product of temporal loop bounds.
    temporal: u64,
    /// Product of spatial loop bounds.
    spatial: u64,
    /// Product of spatial bounds over reduction dims.
    spatial_reduction: u64,
    /// Product of temporal bounds over reduction dims.
    temporal_reduction: u64,
}

impl NestAgg {
    fn of(nest: &[Loop]) -> NestAgg {
        let mut a = NestAgg {
            per_dim: [1; 7],
            temporal: 1,
            spatial: 1,
            spatial_reduction: 1,
            temporal_reduction: 1,
        };
        for l in nest {
            a.per_dim[l.dim.index()] *= l.bound;
            if l.is_spatial() {
                a.spatial *= l.bound;
                if l.dim.is_reduction() {
                    a.spatial_reduction *= l.bound;
                }
            } else {
                a.temporal *= l.bound;
                if l.dim.is_reduction() {
                    a.temporal_reduction *= l.bound;
                }
            }
        }
        a
    }
}

/// Incremental re-evaluation state for neighbor-move search: one
/// instance per (search call, layer), shared across that call's
/// candidate evaluations.
///
/// Two things are cached:
///
/// * the layer's mapping-independent output-transfer term
///   ([`PerfModel::output_movement_cycles`]), computed once;
/// * per-sub-nest aggregate products ([`nest_fingerprint`]-keyed) — a
///   one-factor SA/hill-climb move rewrites exactly one sub-nest, so
///   re-scoring a neighbor recomputes that nest's products and reuses
///   the rest.
///
/// Nothing here depends on scores or the candidate stream, so results
/// are reusable across engines within the call; the state is dropped at
/// the end of the search call (a different layer means different nest
/// meanings). Hit/miss counts feed `CacheStats::delta_{hits,misses}`.
#[derive(Debug, Default)]
pub struct EvalDelta {
    movement: OnceLock<u64>,
    nests: Mutex<HashMap<u64, NestAgg>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl EvalDelta {
    fn nest(&self, nest: &[Loop]) -> NestAgg {
        let fp = nest_fingerprint(nest);
        let mut g = self.nests.lock().unwrap();
        match g.entry(fp) {
            Entry::Occupied(e) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                *e.get()
            }
            Entry::Vacant(v) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                *v.insert(NestAgg::of(nest))
            }
        }
    }

    /// `(hits, misses)` of the per-nest aggregate memo.
    pub fn counts(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

/// The performance model, bound to an architecture.
#[derive(Debug, Clone)]
pub struct PerfModel<'a> {
    pub arch: &'a Arch,
    mul_cycles: u64,
    add_cycles: u64,
    /// Cycles to move one operand between row and column orientation
    /// (transposition read+write of `word_bits` rows).
    transpose_cycles: u64,
    word_bits: u32,
}

impl<'a> PerfModel<'a> {
    pub fn new(arch: &'a Arch) -> Self {
        let word_bits = arch.levels[0].word_bits.max(1);
        // One row access ~ tRCD + tCL (activate + column access); a w-bit
        // bit-serial operand spans w rows; transposition reads and rewrites
        // each of them once.
        let row_cycles = ((arch.timing.t_rcd + arch.timing.t_cl) / arch.clock_ns).ceil() as u64;
        Self {
            arch,
            mul_cycles: arch.op_cycles("mul"),
            add_cycles: arch.op_cycles("add"),
            transpose_cycles: 2 * u64::from(word_bits) * row_cycles,
            word_bits,
        }
    }

    /// Cycles of one MAC (multiply + accumulate-add) in a lane.
    #[inline]
    pub fn mac_cycles(&self) -> u64 {
        self.mul_cycles + self.add_cycles
    }

    /// Latency of one bank-level temporal step of `mapping`.
    pub fn step_cycles(&self, mapping: &Mapping) -> u64 {
        let lanes = self.arch.lanes_per_compute_instance().max(1);
        let red_lanes = mapping.reduction_lanes().max(1);
        // Each output occupies `red_lanes` columns; lanes available for
        // distinct outputs shrink accordingly.
        let effective_lanes = (lanes / red_lanes).max(1);
        let outputs = mapping.outputs_per_step().max(1);
        let waves = ceil_div(outputs, effective_lanes);
        let serial_macs = mapping.macs_per_output().max(1);
        let mut cycles = waves * serial_macs * self.mac_cycles();
        if red_lanes > 1 {
            // Tree reduction across lanes: log2 rounds of transpose + add.
            let rounds = 64 - (red_lanes - 1).leading_zeros() as u64;
            cycles += waves * rounds * (self.transpose_cycles + self.add_cycles);
        }
        cycles
    }

    /// Inter-layer data-movement cycles: the layer's outputs travel from
    /// the producing banks to the next layer's input locations over the
    /// bank/channel links (paper §IV-C "output-input inter-layer data
    /// transfer").
    pub fn output_movement_cycles(&self, layer: &Layer) -> u64 {
        let out_bytes = layer.output_size() * u64::from(self.word_bits) / 8;
        let compute = self.arch.compute_level();
        let bw = self.arch.levels[..=compute]
            .iter()
            .map(|l| l.write_bandwidth.max(l.read_bandwidth))
            .filter(|&b| b > 0)
            .min()
            .unwrap_or(16)
            .max(1);
        // Channels move data in parallel.
        let channels = self
            .arch
            .levels
            .iter()
            .find(|l| l.name.eq_ignore_ascii_case("channel"))
            .map(|l| l.instances)
            .unwrap_or(1)
            .max(1);
        ceil_div(out_bytes, bw * channels)
    }

    /// Cross-bank partial-sum reduction movement for hierarchy-spatial
    /// reduction loops.
    pub fn cross_bank_reduction_cycles(&self, layer: &Layer, mapping: &Mapping) -> u64 {
        let groups: u64 = mapping
            .hierarchy_loops()
            .filter(|(_, l)| l.is_spatial() && l.dim.is_reduction())
            .map(|(_, l)| l.bound)
            .product();
        if groups <= 1 {
            return 0;
        }
        // (groups-1) partial output tensors move and get added in.
        let out_bytes = layer.output_size() * u64::from(self.word_bits) / 8;
        let bw = self.arch.levels[self.arch.compute_level()]
            .write_bandwidth
            .max(1);
        (groups - 1) * (ceil_div(out_bytes, bw) + self.add_cycles)
    }

    /// Evaluate a full (layer, mapping) pair.
    pub fn evaluate(&self, layer: &Layer, mapping: &Mapping) -> LayerStats {
        let step_cycles = self.step_cycles(mapping);
        let temporal_steps = mapping.temporal_steps().max(1);
        let compute_cycles = step_cycles * temporal_steps;
        let movement_cycles =
            self.output_movement_cycles(layer) + self.cross_bank_reduction_cycles(layer, mapping);
        let latency_cycles = compute_cycles + movement_cycles;

        let banks_used = mapping.spatial_instances().max(1);
        let total_banks = self.arch.compute_instances().max(1);
        let lanes = self.arch.lanes_per_compute_instance().max(1);
        let red_lanes = mapping.reduction_lanes().max(1);
        let effective_lanes = (lanes / red_lanes).max(1);
        let outputs = mapping.outputs_per_step().max(1);
        let waves = ceil_div(outputs, effective_lanes);
        let lane_occupancy = outputs as f64 / (waves * effective_lanes) as f64;
        let utilization = (banks_used.min(total_banks) as f64 / total_banks as f64)
            * lane_occupancy
            / mapping.padding_waste(layer);

        let energy_pj = self.energy_pj(layer, mapping);

        LayerStats {
            latency_cycles,
            compute_cycles,
            movement_cycles,
            step_cycles,
            temporal_steps,
            banks_used,
            outputs_per_step: mapping.outputs_per_step(),
            energy_pj,
            utilization,
        }
    }

    /// [`PerfModel::evaluate`] with per-nest delta-state: aggregate bound
    /// products and the layer's fixed transfer term come from `delta`
    /// when already computed there.
    ///
    /// Bit-identical to `evaluate` by construction: the cached values are
    /// exact `u64` products of loop bounds (commutative and associative,
    /// so per-nest grouping changes nothing — and partial products are
    /// sub-products of totals the full path already forms, so no new
    /// overflow), and the floating-point path runs the very same
    /// `padding_waste`/`energy_pj` calls on the mapping's stored bounds.
    pub fn evaluate_cached(
        &self,
        layer: &Layer,
        mapping: &Mapping,
        delta: &EvalDelta,
    ) -> LayerStats {
        let interior = mapping.interior_idx();
        let mut temporal_steps_raw = 1u64;
        let mut spatial_instances = 1u64;
        let mut reduction_groups = 1u64;
        let mut tile = NestAgg::of(&[]);
        for (i, nest) in mapping.nests.iter().enumerate() {
            let agg = delta.nest(nest);
            if i == interior {
                tile = agg;
            } else {
                temporal_steps_raw *= agg.temporal;
                spatial_instances *= agg.spatial;
                reduction_groups *= agg.spatial_reduction;
            }
        }

        // `step_cycles`, from the interior aggregates.
        let lanes = self.arch.lanes_per_compute_instance().max(1);
        let red_lanes = tile.spatial_reduction.max(1);
        let effective_lanes = (lanes / red_lanes).max(1);
        let outputs_per_step = tile.per_dim[Dim::N.index()]
            * tile.per_dim[Dim::K.index()]
            * tile.per_dim[Dim::P.index()]
            * tile.per_dim[Dim::Q.index()];
        let outputs = outputs_per_step.max(1);
        let waves = ceil_div(outputs, effective_lanes);
        let serial_macs = tile.temporal_reduction.max(1);
        let mut step_cycles = waves * serial_macs * self.mac_cycles();
        if red_lanes > 1 {
            let rounds = 64 - (red_lanes - 1).leading_zeros() as u64;
            step_cycles += waves * rounds * (self.transpose_cycles + self.add_cycles);
        }

        let temporal_steps = temporal_steps_raw.max(1);
        let compute_cycles = step_cycles * temporal_steps;

        // Movement: the layer-only transfer term (cached once per call)
        // plus cross-bank reduction from the hierarchy aggregates.
        let transfer = *delta.movement.get_or_init(|| self.output_movement_cycles(layer));
        let cross_bank = if reduction_groups <= 1 {
            0
        } else {
            let out_bytes = layer.output_size() * u64::from(self.word_bits) / 8;
            let bw = self.arch.levels[self.arch.compute_level()].write_bandwidth.max(1);
            (reduction_groups - 1) * (ceil_div(out_bytes, bw) + self.add_cycles)
        };
        let movement_cycles = transfer + cross_bank;
        let latency_cycles = compute_cycles + movement_cycles;

        let banks_used = spatial_instances.max(1);
        let total_banks = self.arch.compute_instances().max(1);
        let lane_occupancy = outputs as f64 / (waves * effective_lanes) as f64;
        let utilization = (banks_used.min(total_banks) as f64 / total_banks as f64)
            * lane_occupancy
            / mapping.padding_waste(layer);

        let energy_pj = self.energy_pj(layer, mapping);

        LayerStats {
            latency_cycles,
            compute_cycles,
            movement_cycles,
            step_cycles,
            temporal_steps,
            banks_used,
            outputs_per_step,
            energy_pj,
            utilization,
        }
    }

    /// Energy model from Table I: each AAP issues two activates and a
    /// precharge (`2·e_ACT` dominates; the GSA terms cover the sense path),
    /// movement pays `e_IO` per transferred bit.
    pub fn energy_pj(&self, layer: &Layer, mapping: &Mapping) -> f64 {
        let e = &self.arch.energy;
        let e_aap = 2.0 * e.e_act + e.e_pre_gsa + e.e_post_gsa;
        let n = u64::from(self.word_bits);
        // AAPs per add and per mul (4n+1 per full addition; a mul is n adds).
        let aaps_add = 4 * n + 1;
        let aaps_mul = n * aaps_add;
        // Total padded MACs actually executed.
        let padded_macs: u64 = Dim::ALL.iter().map(|&d| mapping.bounds[d]).product();
        let compute_pj = padded_macs as f64 * (aaps_add + aaps_mul) as f64 * e_aap
            // all lanes in a bank share the row activation
            / self.arch.lanes_per_compute_instance().max(1) as f64;
        let moved_bits = (layer.output_size() * u64::from(self.word_bits)) as f64;
        compute_pj + moved_bits * e.e_io
    }
}

/// Sequential whole-network latency: the sum of per-layer latencies
/// (layers execute back-to-back without overlap).
pub fn sequential_network_latency(stats: &[LayerStats]) -> u64 {
    stats.iter().map(|s| s.latency_cycles).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Arch;
    use crate::mapping::{Loop, Mapping};
    use crate::mapspace::MapSpace;
    use crate::util::rng::SplitMix64;

    fn layer() -> Layer {
        Layer::conv("t", 1, 16, 8, 8, 8, 3, 3, 1, 1)
    }

    fn mapping() -> Mapping {
        Mapping::new(vec![
            vec![Loop::temporal(Dim::K, 2)],
            vec![Loop::spatial(Dim::P, 4)],
            vec![Loop::temporal(Dim::P, 2), Loop::temporal(Dim::Q, 4)],
            vec![
                Loop::spatial(Dim::K, 8),
                Loop::spatial(Dim::Q, 2),
                Loop::temporal(Dim::C, 8),
                Loop::temporal(Dim::R, 3),
                Loop::temporal(Dim::S, 3),
            ],
        ])
    }

    #[test]
    fn step_cycles_hand_computed() {
        let arch = Arch::dram_pim_small();
        let pm = PerfModel::new(&arch);
        // outputs/step = 16, lanes = 64 -> 1 wave; 72 serial MACs;
        // mac = 980 + 196 = 1176 cycles.
        assert_eq!(pm.step_cycles(&mapping()), 72 * 1176);
    }

    #[test]
    fn latency_composition() {
        let arch = Arch::dram_pim_small();
        let pm = PerfModel::new(&arch);
        let l = layer();
        let m = mapping();
        let st = pm.evaluate(&l, &m);
        assert_eq!(st.temporal_steps, 16);
        assert_eq!(st.compute_cycles, 16 * st.step_cycles);
        assert_eq!(st.latency_cycles, st.compute_cycles + st.movement_cycles);
        assert!(st.movement_cycles > 0);
        assert!(st.energy_pj > 0.0);
        assert!(st.utilization > 0.0 && st.utilization <= 1.0);
    }

    #[test]
    fn more_banks_fewer_steps_is_faster() {
        // Spreading work over more banks must not be slower in compute.
        let arch = Arch::dram_pim_small();
        let pm = PerfModel::new(&arch);
        let l = layer();
        let wide = mapping(); // 4 banks
        let mut narrow_nests = wide.nests.clone();
        narrow_nests[1] = vec![]; // drop the spatial P split
        narrow_nests[2].push(Loop::temporal(Dim::P, 4)); // serialize it
        let narrow = Mapping::new(narrow_nests);
        let fast = pm.evaluate(&l, &wide);
        let slow = pm.evaluate(&l, &narrow);
        assert!(fast.compute_cycles < slow.compute_cycles);
    }

    #[test]
    fn lane_reduction_charges_extra() {
        let arch = Arch::dram_pim_small();
        let pm = PerfModel::new(&arch);
        // Same tile, but C split across 4 lanes spatially.
        let base = Mapping::new(vec![
            vec![],
            vec![],
            vec![Loop::temporal(Dim::K, 2), Loop::temporal(Dim::P, 8), Loop::temporal(Dim::Q, 8)],
            vec![
                Loop::spatial(Dim::K, 8),
                Loop::temporal(Dim::C, 8),
                Loop::temporal(Dim::R, 3),
                Loop::temporal(Dim::S, 3),
            ],
        ]);
        let lane_red = Mapping::new(vec![
            vec![],
            vec![],
            vec![Loop::temporal(Dim::K, 2), Loop::temporal(Dim::P, 8), Loop::temporal(Dim::Q, 8)],
            vec![
                Loop::spatial(Dim::K, 8),
                Loop::spatial(Dim::C, 4),
                Loop::temporal(Dim::C, 2),
                Loop::temporal(Dim::R, 3),
                Loop::temporal(Dim::S, 3),
            ],
        ]);
        // Serial MACs drop 8->2 but reduction movement appears.
        let a = pm.step_cycles(&base);
        let b = pm.step_cycles(&lane_red);
        assert!(b < a, "lane reduction should shorten the serial chain");
        let only_macs = 2 * 3 * 3 * pm.mac_cycles();
        assert!(b > only_macs, "reduction rounds must be charged");
    }

    #[test]
    fn cross_bank_reduction_counted() {
        let arch = Arch::dram_pim_small();
        let pm = PerfModel::new(&arch);
        let l = layer();
        let m = Mapping::new(vec![
            vec![],
            vec![Loop::spatial(Dim::C, 4)],
            vec![
                Loop::temporal(Dim::K, 2),
                Loop::temporal(Dim::P, 8),
                Loop::temporal(Dim::Q, 8),
            ],
            vec![
                Loop::spatial(Dim::K, 8),
                Loop::temporal(Dim::C, 2),
                Loop::temporal(Dim::R, 3),
                Loop::temporal(Dim::S, 3),
            ],
        ]);
        assert!(pm.cross_bank_reduction_cycles(&l, &m) > 0);
        assert_eq!(pm.cross_bank_reduction_cycles(&l, &mapping()), 0);
    }

    #[test]
    fn cached_evaluation_matches_and_hits() {
        let arch = Arch::dram_pim_small();
        let pm = PerfModel::new(&arch);
        let l = layer();
        let delta = EvalDelta::default();
        let m = mapping();
        assert_eq!(pm.evaluate(&l, &m), pm.evaluate_cached(&l, &m, &delta));
        let (h0, m0) = delta.counts();
        assert_eq!(h0, 0, "cold state cannot hit");
        assert_eq!(m0, m.nests.len() as u64);
        // Re-evaluating the same mapping hits every nest and recomputes
        // nothing.
        assert_eq!(pm.evaluate(&l, &m), pm.evaluate_cached(&l, &m, &delta));
        let (h1, m1) = delta.counts();
        assert_eq!(m1, m0);
        assert_eq!(h1, m.nests.len() as u64);
    }

    #[test]
    fn cached_evaluation_is_bit_identical_on_samples() {
        let arch = Arch::dram_pim_small();
        let pm = PerfModel::new(&arch);
        let l = layer();
        let ms = MapSpace::with_defaults(&arch, &l);
        let delta = EvalDelta::default();
        let mut rng = SplitMix64::new(23);
        let mut seen = 0;
        for _ in 0..60 {
            if let Some(m) = ms.sample(&mut rng) {
                seen += 1;
                // `assert_eq!` on LayerStats covers the f64 fields too:
                // the delta path must be exact, not approximately equal.
                assert_eq!(pm.evaluate(&l, &m), pm.evaluate_cached(&l, &m, &delta));
            }
        }
        assert!(seen > 0, "sampler produced no mappings");
        let (_, misses) = delta.counts();
        assert!(misses > 0);
    }

    #[test]
    fn sampled_mappings_have_positive_stats() {
        let arch = Arch::dram_pim_small();
        let pm = PerfModel::new(&arch);
        let l = layer();
        let ms = MapSpace::with_defaults(&arch, &l);
        let mut rng = SplitMix64::new(11);
        for _ in 0..50 {
            if let Some(m) = ms.sample(&mut rng) {
                let st = pm.evaluate(&l, &m);
                assert!(st.latency_cycles > 0);
                assert!(st.utilization > 0.0 && st.utilization <= 1.0 + 1e-9);
            }
        }
    }
}
